//! Per-layer sparse-format execution planning.
//!
//! Compression only pays when the executor can exploit it: an 80%-pruned
//! layer in scalar CSR can still lose to the dense blocked micro-kernel,
//! and a block format only wins when the nonzeros actually cluster. The
//! planner closes that loop. Given a pruned layer (its [`CsrMatrix`],
//! GEMM row count and HWIO weight shape) and a [`FormatPolicy`], it
//! chooses Dense / CSR / BSR{br,bc} / Pattern — plus whether
//! filter-kernel reordering ([`crate::compress::reorder`]) is worth
//! carrying and which serial→parallel cutover the kernels should use —
//! and records every choice in an [`ExecPlan`] that the executor
//! dispatches on and the artifact manifest serializes.
//!
//! The Pattern format ([`crate::compress::pattern`]) is only considered
//! for spatial convolutions whose kernels fit the pattern table
//! (`1 < kh*kw <= 16`); it wins on *pattern-pruned* profiles (the PatDNN
//! regime `docs/PIPELINE.md` walks through) where it stores no padding
//! and amortizes one index over each kernel's entries.
//!
//! Two modes, mirroring the tuner's split:
//! - **heuristic** ([`choose`]): a relative cost model over exact fill
//!   counts (no densification, no timing) — the default, used at every
//!   instance build;
//! - **measured** ([`choose_measured`]): the heuristic shortlist timed
//!   with the real kernels on the layer's own shape, the same
//!   micro-benchmark loop the tile tuner runs — enabled with the tuner
//!   (`EngineBuilder::tuned(true)`).
//!
//! On top of both sits the **search-based tuner** ([`search`] +
//! [`db`]): a branch-and-bound search over the full compositional space
//! (format x block shape x reorder x value width x cutover), priced
//! through a per-device [`db::CostTable`] generation and memoized in a
//! persistent plan database (`EngineBuilder::plan_db`, `cadnn plan
//! --tune --plan-db`), so tuning cost is paid once per (shape,
//! structure, device) family across builds and models — see
//! `docs/PLANDB.md`. [`PlanCache::plan_node`] is the build-time entry
//! point that arbitrates memo → database → search → legacy planning.
//!
//! The cost constants are relative per-value costs calibrated against
//! this crate's kernels (see `docs/FORMATS.md` for the derivation and
//! `benches/bench_sparse_formats.rs` for the regeneration harness).

pub mod db;
pub mod search;

use crate::compress::bsr;
use crate::compress::bsr::BsrMatrix;
use crate::compress::csr::CsrMatrix;
use crate::compress::pattern;
use crate::compress::pattern::PatternMatrix;
use crate::compress::qsparse::ValueBits;
use crate::compress::reorder;
use crate::compress::reorder::Permutation;
use crate::kernels::{Epilogue, PARALLEL_M_CUTOVER};
use crate::passes::layout::TileConfig;
use crate::util::json::{obj, Json};
use crate::util::stats;
use std::collections::BTreeMap;
use std::sync::Arc;

/// How a layer's weights are stored and which kernel runs it.
///
/// # Examples
///
/// ```
/// use cadnn::planner::SparseFormat;
///
/// // labels are the stable manifest encoding and parse back losslessly
/// for f in [
///     SparseFormat::Dense,
///     SparseFormat::Csr,
///     SparseFormat::Bsr { br: 4, bc: 4 },
///     SparseFormat::Pattern,
/// ] {
///     assert_eq!(SparseFormat::parse(&f.label()), Some(f));
/// }
/// assert_eq!(SparseFormat::parse("coo"), None);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseFormat {
    /// Dense matrix + blocked GEMM (pruned zeros rematerialized).
    Dense,
    /// Element-granular CSR + scalar-indexed kernel.
    Csr,
    /// Block-CSR with (br x bc) blocks + register-blocked kernel.
    Bsr { br: usize, bc: usize },
    /// PatDNN per-kernel patterns + shared pattern table
    /// ([`crate::compress::pattern`]) + kernel-accumulator micro-kernel.
    Pattern,
}

impl SparseFormat {
    /// Stable textual name (`dense`, `csr`, `bsr4x1`, `pattern`, ...) —
    /// the manifest encoding.
    pub fn label(&self) -> String {
        match self {
            SparseFormat::Dense => "dense".to_string(),
            SparseFormat::Csr => "csr".to_string(),
            SparseFormat::Bsr { br, bc } => format!("bsr{br}x{bc}"),
            SparseFormat::Pattern => "pattern".to_string(),
        }
    }

    /// Inverse of [`SparseFormat::label`].
    pub fn parse(s: &str) -> Option<SparseFormat> {
        match s {
            "dense" => Some(SparseFormat::Dense),
            "csr" => Some(SparseFormat::Csr),
            "pattern" => Some(SparseFormat::Pattern),
            _ => {
                let rest = s.strip_prefix("bsr")?;
                let (a, b) = rest.split_once('x')?;
                let (br, bc) = (a.parse().ok()?, b.parse().ok()?);
                if br == 0 || bc == 0 {
                    return None;
                }
                Some(SparseFormat::Bsr { br, bc })
            }
        }
    }
}

/// User-facing value-precision policy (`EngineBuilder::value_bits`) —
/// the second, orthogonal axis next to [`FormatPolicy`]: *how a sparse
/// payload's values are stored*, independent of which format stores
/// them. The resolved per-layer decision is
/// [`crate::compress::qsparse::ValueBits`] in `LayerPlan::value_bits`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum ValuePolicy {
    /// Follow the profile: a layer whose compress report exported a
    /// codebook (`SparsityProfile::quant`) gets a quantized payload at
    /// the exported width; everything else stays f32. This is how a
    /// python-side unified prune+quantize run propagates into native
    /// execution without any per-model flags.
    #[default]
    Auto,
    /// Pin every payload to raw f32 values (the pre-quantization
    /// behavior, and the only choice for Dense layers).
    F32,
    /// Pin every sparse payload to an 8-bit codebook.
    Q8,
    /// Pin every sparse payload to a 4-bit codebook.
    Q4,
}

impl ValuePolicy {
    /// Stable textual name (`auto`, `f32`, `q8`, `q4`) — the CLI
    /// encoding (`cadnn plan --value-bits`).
    pub fn label(&self) -> &'static str {
        match self {
            ValuePolicy::Auto => "auto",
            ValuePolicy::F32 => "f32",
            ValuePolicy::Q8 => "q8",
            ValuePolicy::Q4 => "q4",
        }
    }

    /// Inverse of [`ValuePolicy::label`].
    pub fn parse(s: &str) -> Option<ValuePolicy> {
        match s {
            "auto" => Some(ValuePolicy::Auto),
            "f32" => Some(ValuePolicy::F32),
            "q8" => Some(ValuePolicy::Q8),
            "q4" => Some(ValuePolicy::Q4),
            _ => None,
        }
    }
}

/// Resolve the per-layer value precision from the policy, the profile's
/// exported codebook width (`declared`, from
/// `SparsityProfile::quant_bits`), and the chosen format. Dense payloads
/// are always f32 — the blocked GEMM has no LUT path, and shallow
/// pruning is not where storage hurts.
pub fn resolve_value_bits(
    policy: ValuePolicy,
    declared: Option<u8>,
    format: SparseFormat,
) -> ValueBits {
    if format == SparseFormat::Dense {
        return ValueBits::F32;
    }
    match policy {
        ValuePolicy::F32 => ValueBits::F32,
        ValuePolicy::Q8 => ValueBits::Q8,
        ValuePolicy::Q4 => ValueBits::Q4,
        ValuePolicy::Auto => match declared {
            Some(b) if b <= 4 => ValueBits::Q4,
            Some(_) => ValueBits::Q8,
            None => ValueBits::F32,
        },
    }
}

/// User-facing format policy (`EngineBuilder::sparse_format`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum FormatPolicy {
    /// Planner decides per layer (never knowingly worse than CSR).
    #[default]
    Auto,
    /// Pin every pruned layer to element-granular CSR (the pre-planner
    /// behavior).
    Csr,
    /// Pin every pruned layer to the best-filling BSR block shape.
    Bsr,
    /// Pin every eligible spatial conv layer to the PatDNN pattern
    /// format; ineligible layers (1x1 / GEMM, or kernels larger than the
    /// pattern table supports) keep the CSR baseline.
    Pattern,
}

impl FormatPolicy {
    /// Stable textual name (`auto`, `csr`, `bsr`, `pattern`) — the CLI
    /// (`cadnn plan --format`) and plan-database encoding.
    pub fn label(&self) -> &'static str {
        match self {
            FormatPolicy::Auto => "auto",
            FormatPolicy::Csr => "csr",
            FormatPolicy::Bsr => "bsr",
            FormatPolicy::Pattern => "pattern",
        }
    }

    /// Inverse of [`FormatPolicy::label`].
    pub fn parse(s: &str) -> Option<FormatPolicy> {
        match s {
            "auto" => Some(FormatPolicy::Auto),
            "csr" => Some(FormatPolicy::Csr),
            "bsr" => Some(FormatPolicy::Bsr),
            "pattern" => Some(FormatPolicy::Pattern),
            _ => None,
        }
    }
}

/// Whether the pattern format can encode a layer of this HWIO shape:
/// a spatial kernel whose `kh*kw` positions fit the pattern table
/// ([`pattern::MAX_POSITIONS`]), with the (K, N) view consistent.
pub fn pattern_eligible(csr: &CsrMatrix, hwio: [usize; 4]) -> bool {
    let kk = hwio[0] * hwio[1];
    (2..=pattern::MAX_POSITIONS).contains(&kk)
        && hwio[2] > 0
        && csr.rows == kk * hwio[2]
        && csr.cols == hwio[3]
}

/// One layer's execution decision.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub format: SparseFormat,
    /// How the payload's values are stored: raw f32 or a packed
    /// 8/4-bit codebook executed through the LUT kernels
    /// ([`crate::kernels::lut`]). Orthogonal to `format`; always
    /// [`ValueBits::F32`] for Dense.
    pub value_bits: ValueBits,
    /// Carry a filter-kernel column permutation with the weights.
    pub reorder: bool,
    /// Serial→parallel row cutover for this layer's kernel.
    pub parallel_cutover: usize,
    /// Estimated execution cost of ONE GEMM row of this layer under the
    /// chosen format, in the relative cost units below (one CSR stored
    /// value = 1.0). Feeds [`ExecPlan::cost_at`] / [`BatchCost`] so the
    /// serving scheduler can reason about batch sizes. `0.0` = unknown
    /// (plans loaded from pre-cost manifests).
    pub cost_per_row: f64,
    /// GEMM rows one image contributes to this layer (convolution:
    /// output pixels; fully-connected: 1). With `cost_per_row` this
    /// makes the plan's cost batch-size-aware: the layer runs
    /// `batch * rows_per_image` rows. `0` = unknown.
    pub rows_per_image: usize,
}

impl LayerPlan {
    /// The CSR-only baseline plan (pre-planner behavior).
    pub fn csr() -> LayerPlan {
        LayerPlan {
            format: SparseFormat::Csr,
            value_bits: ValueBits::F32,
            reorder: false,
            parallel_cutover: PARALLEL_M_CUTOVER,
            cost_per_row: 0.0,
            rows_per_image: 0,
        }
    }

    fn with_format(format: SparseFormat, reorder: bool) -> LayerPlan {
        LayerPlan { format, reorder, ..LayerPlan::csr() }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", Json::Str(self.format.label())),
            ("value_bits", Json::Num(self.value_bits.bits() as f64)),
            ("reorder", Json::Bool(self.reorder)),
            ("cutover", Json::Num(self.parallel_cutover as f64)),
            ("cost_per_row", Json::Num(self.cost_per_row)),
            ("rows_per_image", Json::Num(self.rows_per_image as f64)),
        ])
    }

    /// Missing optional fields default (value_bits=32 — the pre-
    /// quantization manifest fallback — reorder=false, cutover=default,
    /// costs unknown); an unknown format string or value width rejects
    /// the whole plan.
    pub fn from_json(j: &Json) -> Option<LayerPlan> {
        let format = SparseFormat::parse(j.get("format")?.as_str()?)?;
        let value_bits = match j.get("value_bits") {
            None => ValueBits::F32,
            Some(v) => ValueBits::from_bits(v.as_usize()?)?,
        };
        Some(LayerPlan {
            format,
            value_bits,
            reorder: j.get("reorder").and_then(|v| v.as_bool()).unwrap_or(false),
            parallel_cutover: j
                .get("cutover")
                .and_then(|v| v.as_usize())
                .unwrap_or(PARALLEL_M_CUTOVER),
            cost_per_row: j.get("cost_per_row").and_then(|v| v.as_f64()).unwrap_or(0.0),
            rows_per_image: j.get("rows_per_image").and_then(|v| v.as_usize()).unwrap_or(0),
        })
    }
}

/// The whole model's per-layer decisions, keyed by layer name. Emitted by
/// `ModelInstance::build_planned`, serialized into the artifact manifest
/// (`runtime::manifest`), surfaced by `cadnn plan`.
///
/// # Examples
///
/// ```
/// use cadnn::planner::{ExecPlan, LayerPlan, SparseFormat};
///
/// let mut plan = ExecPlan::default();
/// plan.layers.insert("c1".into(), LayerPlan::csr());
/// plan.layers.insert(
///     "c2".into(),
///     LayerPlan {
///         format: SparseFormat::Pattern,
///         parallel_cutover: 192,
///         cost_per_row: 64.0,
///         rows_per_image: 196,
///         ..LayerPlan::csr()
///     },
/// );
/// // the manifest encoding round-trips losslessly
/// let json = plan.to_json().to_string_pretty();
/// let back = ExecPlan::from_json(&cadnn::util::json::Json::parse(&json).unwrap()).unwrap();
/// assert_eq!(back, plan);
/// assert_eq!(back.format_counts()["pattern"], 1);
/// // ...and the per-layer costs make the plan batch-size-aware
/// assert!(back.cost_at(8).unwrap() > back.cost_at(1).unwrap());
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecPlan {
    pub layers: BTreeMap<String, LayerPlan>,
}

impl ExecPlan {
    pub fn get(&self, layer: &str) -> Option<&LayerPlan> {
        self.layers.get(layer)
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// format label -> how many layers chose it (CLI summary).
    pub fn format_counts(&self) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        for lp in self.layers.values() {
            *out.entry(lp.format.label()).or_insert(0) += 1;
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<(String, Json)> = self
            .layers
            .iter()
            .map(|(name, lp)| (name.clone(), lp.to_json()))
            .collect();
        Json::Obj(vec![("layers".to_string(), Json::Obj(layers))])
    }

    /// `None` on anything malformed — callers treat that as "no plan"
    /// (the old-manifest fallback).
    pub fn from_json(j: &Json) -> Option<ExecPlan> {
        let Json::Obj(kv) = j.get("layers")? else {
            return None;
        };
        let mut layers = BTreeMap::new();
        for (name, v) in kv {
            layers.insert(name.clone(), LayerPlan::from_json(v)?);
        }
        Some(ExecPlan { layers })
    }

    /// Summed per-image cost of the planned layers (cost units): one
    /// image contributes `rows_per_image` GEMM rows to each layer at
    /// `cost_per_row` units each. `0.0` when the plan carries no cost
    /// information (empty plan, or one loaded from a pre-cost manifest).
    pub fn per_image_cost(&self) -> f64 {
        self.layers
            .values()
            .map(|lp| lp.cost_per_row * lp.rows_per_image as f64)
            .sum()
    }

    /// Estimated cost (units) of executing one batch of `m` images under
    /// this plan — the planner cost model the serving scheduler runs on
    /// ([`crate::serve::Scheduler`]). `None` when the plan carries no
    /// cost information, so callers fall back to a plain batching policy.
    pub fn cost_at(&self, m: usize) -> Option<f64> {
        BatchCost::from_plan(self).map(|c| c.cost_at(m))
    }
}

/// Batch-size cost estimator distilled from an [`ExecPlan`]: a fixed
/// per-dispatch overhead plus a per-image term, both in the relative
/// cost units below. Larger batches amortize the overhead (higher
/// throughput) at the price of a longer wall-clock run (worse tail
/// latency) — exactly the tradeoff a deadline-aware scheduler arbitrates.
///
/// # Examples
///
/// ```
/// use cadnn::planner::{BatchCost, COST_BATCH_OVERHEAD};
///
/// let c = BatchCost { per_image: 500.0, overhead: COST_BATCH_OVERHEAD };
/// // total cost grows with m...
/// assert!(c.cost_at(8) > c.cost_at(1));
/// // ...but the cost *per image* shrinks (overhead amortizes)
/// assert!(c.cost_at(8) / 8.0 < c.cost_at(1));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchCost {
    /// Cost units added by every image in the batch.
    pub per_image: f64,
    /// Fixed cost units per executed batch (dispatch, staging, the
    /// unplanned layers' envelope).
    pub overhead: f64,
}

impl BatchCost {
    /// Distill a plan's per-layer costs; `None` when the plan carries no
    /// cost information.
    pub fn from_plan(plan: &ExecPlan) -> Option<BatchCost> {
        let per_image = plan.per_image_cost();
        if per_image > 0.0 {
            Some(BatchCost { per_image, overhead: COST_BATCH_OVERHEAD })
        } else {
            None
        }
    }

    /// Estimated cost (units) of one batch of `m` images.
    pub fn cost_at(&self, m: usize) -> f64 {
        self.overhead + m as f64 * self.per_image
    }

    /// Estimated wall-clock µs of one batch of `m` images under a
    /// calibrated units→µs scale (the serving scheduler's
    /// `us_per_unit`). 0.0 for degenerate scales.
    pub fn est_us(&self, m: usize, us_per_unit: f64) -> f64 {
        if !(us_per_unit > 0.0) {
            return 0.0;
        }
        self.cost_at(m) * us_per_unit
    }

    /// Calibrated serving capacity at batch size `m`, in images per
    /// second: `m` images every `est_us(m)` microseconds, back to back.
    /// The admission controller's notion of "calibrated capacity" — an
    /// offered rate above `capacity_rps(max_batch)` *must* shed or miss.
    /// 0.0 when the scale or the cost is degenerate.
    pub fn capacity_rps(&self, m: usize, us_per_unit: f64) -> f64 {
        let est = self.est_us(m, us_per_unit);
        if !(est > 0.0) || m == 0 {
            return 0.0;
        }
        m as f64 * 1e6 / est
    }
}

// ---------------------------------------------------------------------------
// Relative cost model (heuristic mode)
//
// Unit: the cost of one CSR stored value (one indexed scalar FMA with a
// scattered accumulate) = 1.0. The others are per-value throughput ratios
// measured against this crate's kernels on the bench harness's
// ResNet-50 shapes; regenerate with `cargo bench --bench
// bench_sparse_formats` and see docs/FORMATS.md before retuning.
// ---------------------------------------------------------------------------

/// Dense blocked GEMM cost per MAC (register-tiled, load-hoisted; ~6-8x
/// the per-value throughput of the scalar CSR kernel).
pub const COST_DENSE_MAC: f64 = 0.15;
/// CSR cost per stored value — the unit.
pub const COST_CSR_NNZ: f64 = 1.0;
/// BSR 4x1 cost per stored value (one index per 4 values, contiguous
/// reduction run, still scalar-width output).
pub const COST_BSR_4X1: f64 = 0.55;
/// BSR 4x4 cost per stored value (one index per 16 values, 4-wide
/// vectorizable accumulator strip).
pub const COST_BSR_4X4: f64 = 0.30;
/// Pattern cost per stored value (contiguous values, activation gather
/// at precomputed offsets, register accumulator — and *no padding*:
/// stored values are exactly nnz).
pub const COST_PATTERN_VAL: f64 = 0.45;
/// Pattern cost per surviving kernel (column index + pattern id load +
/// one output update), in the same per-CSR-value unit. Scattered
/// sparsity degrades toward 1-2 entries per kernel, where this term
/// keeps Auto on the CSR baseline; pattern-pruned layers amortize it
/// over a full pattern (4+ entries) per kernel.
pub const COST_PATTERN_KERNEL: f64 = 0.80;
/// Per-stored-value cost multiplier of the 8-bit LUT kernels relative
/// to their f32 counterparts: one byte-index load plus a dependent
/// codebook gather replaces the f32 value load. The 256-entry table
/// lives in L1, so the penalty is small and partially offset by the 4x
/// smaller value stream.
pub const COST_LUT_Q8: f64 = 1.05;
/// Per-stored-value cost multiplier of the 4-bit LUT kernels: the
/// nibble unpack (shift+mask) adds ALU work on top of the gather; the
/// 16-entry table is register-resident. Applied in heuristic and
/// measured modes alike (both price plans through [`lut_cost_factor`]).
pub const COST_LUT_Q4: f64 = 1.12;

/// The [`COST_LUT_Q8`]/[`COST_LUT_Q4`] multiplier for a value width
/// (1.0 for f32).
pub fn lut_cost_factor(v: ValueBits) -> f64 {
    match v {
        ValueBits::F32 => 1.0,
        ValueBits::Q8 => COST_LUT_Q8,
        ValueBits::Q4 => COST_LUT_Q4,
    }
}

/// A non-CSR format must beat the CSR estimate by this factor before
/// Auto switches away from the baseline (GEMM-shaped layers).
pub const AUTO_SWITCH_MARGIN: f64 = 0.85;
/// Stricter margin for spatial (im2col) convolutions, whose activation
/// panels make the estimates noisier.
pub const SPATIAL_SWITCH_MARGIN: f64 = 0.75;
/// Reordering must cut the stored-block count by at least this factor
/// before the plan carries a permutation (the output scatter isn't free).
pub const REORDER_MIN_GAIN: f64 = 0.90;
/// Fixed per-batch dispatch cost (units) in [`BatchCost`]: queue
/// hand-off, input staging, epilogues, and the unplanned (dense) layers'
/// envelope. Makes `cost_at(m)` affine rather than linear, so larger
/// batches amortize — the serving scheduler calibrates the units→µs
/// scale from observed batches, so only the *ratio* to the per-value
/// costs above matters here.
pub const COST_BATCH_OVERHEAD: f64 = 1_000.0;

/// Block shapes Auto considers, with their per-stored-value costs.
pub const BSR_CANDIDATES: [(usize, usize, f64); 2] =
    [(4, 1, COST_BSR_4X1), (4, 4, COST_BSR_4X4)];

fn bsr_cost(br: usize, bc: usize) -> f64 {
    BSR_CANDIDATES
        .iter()
        .find(|(a, b, _)| *a == br && *b == bc)
        .map(|(_, _, c)| *c)
        .unwrap_or(COST_BSR_4X1)
}

// ---------------------------------------------------------------------------
// Build-time artifact cache
// ---------------------------------------------------------------------------

/// Memoized per-layer planning artifacts: candidate block counts, the
/// column-clustering [`Permutation`], and the densified weight matrix.
/// The planner's estimate and the instance's payload rewrite both
/// consume these, so clustering/densification run **once per pruned
/// layer** instead of once in the estimate plus once per batch variant —
/// without the permutation ever entering the serialized [`ExecPlan`].
#[derive(Debug, Default)]
pub struct LayerArtifacts {
    /// (rows, cols, nnz, content fingerprint) of the matrix these
    /// artifacts were computed for — a stale-entry guard for cross-build
    /// cache reuse. The fingerprint covers support *and* values, so two
    /// same-shape matrices pruned to the same exact nnz (the density-
    /// exact cut makes that collision easy) can never alias.
    key: Option<(usize, usize, usize, u64)>,
    /// (br, bc) -> (stored block count, reorder worthwhile).
    blocks: BTreeMap<(usize, usize), (usize, bool)>,
    /// br -> column-clustering permutation.
    perms: BTreeMap<usize, Permutation>,
    /// Densified weights (shared, cheap to hand out).
    dense: Option<Arc<Vec<f32>>>,
}

impl LayerArtifacts {
    /// (block count, reorder worthwhile) for one candidate block shape,
    /// memoized.
    fn blocks_for(&mut self, csr: &CsrMatrix, br: usize, bc: usize) -> (usize, bool) {
        if let Some(&hit) = self.blocks.get(&(br, bc)) {
            return hit;
        }
        let plain = bsr::count_blocks(csr, br, bc);
        let result = if bc <= 1 || plain == 0 {
            (plain, false)
        } else {
            let perm = self.permutation(csr, br);
            let mapped = bsr::count_blocks_mapped(csr, br, bc, &perm.inverse().perm);
            if (mapped as f64) < plain as f64 * REORDER_MIN_GAIN {
                (mapped, true)
            } else {
                (plain, false)
            }
        };
        self.blocks.insert((br, bc), result);
        result
    }

    /// The column-clustering permutation for `br`-row stripes, computed
    /// at most once per layer. The instance build reuses exactly this
    /// permutation for the payload rewrite, so plan and payload agree by
    /// construction.
    pub fn permutation(&mut self, csr: &CsrMatrix, br: usize) -> &Permutation {
        self.perms
            .entry(br)
            .or_insert_with(|| reorder::cluster_columns_csr(csr, br))
    }

    /// The densified weight matrix, computed at most once per layer.
    pub fn dense(&mut self, csr: &CsrMatrix) -> Arc<Vec<f32>> {
        self.dense.get_or_insert_with(|| Arc::new(csr.to_dense())).clone()
    }
}

/// Cross-batch-variant build cache, held by one engine build
/// (`EngineBuilder` creates one and threads it through every
/// `ModelInstance::build_planned_cached` call): [`LayerArtifacts`] keyed
/// by layer name, plus the per-layer-family PatDNN pattern library so
/// tuned ResNet-50 builds don't re-run library selection for every layer
/// with the same (kh, kw, cin) shape.
///
/// It is also the build-time face of the plan-tuning subsystem: an
/// attached [`db::PlanDb`] and/or the `tune` flag switch
/// [`PlanCache::plan_node`] from the legacy heuristic/measured planners
/// to the [`search`] module, with an in-process memo keyed by the same
/// [`db::SpecKey`] the database uses — so "same layer" means the same
/// thing in memory and on disk, and a layer that differs only by batch
/// variant never re-measures ([`db::TuneStats`] counts all of this).
#[derive(Debug, Default)]
pub struct PlanCache {
    layers: BTreeMap<String, LayerArtifacts>,
    /// Persistent plan database, when the build attached one
    /// (`EngineBuilder::plan_db` / `cadnn plan --plan-db`).
    db: Option<db::PlanDb>,
    /// Search with beam measurement (`EngineBuilder::tune_plans` /
    /// `cadnn plan --tune`).
    tune: bool,
    /// In-process spec-key memo: batch variants of one layer (and
    /// same-spec layers across models in one build) plan once.
    memo: BTreeMap<db::SpecKey, LayerPlan>,
    stats: db::TuneStats,
    /// (kh, kw, cin, entries) -> the family's resolved pattern
    /// libraries, each tagged with the weight fingerprint it was
    /// resolved FOR (selection or a passed fit check), so identical
    /// weights — the same layer across batch variants — exact-hit
    /// without re-scoring. More than one distinct library means the
    /// family's layers had magnitude layouts too different for one
    /// library ([`LIBRARY_FIT_THRESHOLD`]).
    pattern_libs: BTreeMap<(usize, usize, usize, usize), Vec<(u64, Arc<Vec<Vec<u8>>>)>>,
}

/// Minimum [`pattern::library_fit`] a cached family library must score
/// on a layer's own weights before [`PlanCache::pattern_library`] hands
/// it out. Below this, the cache re-selects from the layer's weights
/// instead of silently reusing another layer's patterns (the PR-4
/// aliasing bug: every same-(kh, kw, cin) layer inherited the *first*
/// layer's library regardless of fit). Same-layer reuse across batch
/// variants always passes (a library fits its own weights at ~1.0);
/// 0.80 keeps PatDNN's library-transfer win for homogeneous layers
/// while catching genuinely mismatched magnitude layouts.
pub const LIBRARY_FIT_THRESHOLD: f64 = 0.80;

/// FNV-1a over a dense weight slice's bit patterns — the exact-weights
/// key of [`PlanCache::pattern_library`].
fn weights_fingerprint(mat: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mix = |h: u64, x: u64| (h ^ x).wrapping_mul(0x100000001b3);
    h = mix(h, mat.len() as u64);
    for &v in mat {
        h = mix(h, v.to_bits() as u64);
    }
    h
}

/// FNV-1a over a CSR matrix's support and values (bit patterns), the
/// content part of the [`LayerArtifacts`] stale-entry key. O(nnz) — the
/// same order as one `count_blocks` pass.
fn csr_fingerprint(csr: &CsrMatrix) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mix = |h: u64, x: u64| (h ^ x).wrapping_mul(0x100000001b3);
    h = mix(h, csr.rows as u64);
    h = mix(h, csr.cols as u64);
    for &c in &csr.col_idx {
        h = mix(h, c as u64);
    }
    for &p in &csr.row_ptr {
        h = mix(h, p as u64);
    }
    for &v in &csr.values {
        h = mix(h, v.to_bits() as u64);
    }
    h
}

impl PlanCache {
    /// The artifacts slot for `name`, reset if the cached entry was
    /// computed for a different matrix — shape, nnz, and a content
    /// fingerprint all have to match, so a caller-held cache reused
    /// across builds can never serve another matrix's permutation or
    /// densified weights (layer names are unique within one build, but
    /// the cache is a public type).
    pub fn layer(&mut self, name: &str, csr: &CsrMatrix) -> &mut LayerArtifacts {
        let key = (csr.rows, csr.cols, csr.nnz(), csr_fingerprint(csr));
        let e = self.layers.entry(name.to_string()).or_default();
        if e.key != Some(key) {
            *e = LayerArtifacts { key: Some(key), ..LayerArtifacts::default() };
        }
        e
    }

    /// The pattern library for a layer of the (kh, kw, cin) family,
    /// selected from `mat`'s own weights the first time and *reused for
    /// later family members only when it actually fits them*. Lookup
    /// order:
    ///
    /// 1. **exact weights** (content fingerprint) — the same layer
    ///    across batch variants resolves without re-scoring or
    ///    re-selecting, even for layers whose own best library scores
    ///    below the threshold (possible: a family is capped at
    ///    [`pattern::DEFAULT_LIBRARY`] masks);
    /// 2. **fit check** — each distinct cached library is scored with
    ///    [`pattern::library_fit`]; the first at or above
    ///    [`LIBRARY_FIT_THRESHOLD`] transfers (PatDNN's cross-layer
    ///    claim), and the match is memoized under this fingerprint;
    /// 3. **fresh selection** otherwise, memoized likewise.
    ///
    /// This keeps the library-transfer win without the aliasing failure
    /// where every same-shape layer silently inherited the first
    /// layer's patterns, and without re-running selection per batch
    /// variant when no cached library fits.
    pub fn pattern_library(
        &mut self,
        kh: usize,
        kw: usize,
        cin: usize,
        entries: usize,
        cols: usize,
        mat: &[f32],
    ) -> Arc<Vec<Vec<u8>>> {
        let fp = weights_fingerprint(mat);
        let libs = self.pattern_libs.entry((kh, kw, cin, entries)).or_default();
        if let Some((_, lib)) = libs.iter().find(|(f, _)| *f == fp) {
            return lib.clone();
        }
        let mut distinct: Vec<&Arc<Vec<Vec<u8>>>> = Vec::new();
        for (_, lib) in libs.iter() {
            if !distinct.iter().any(|d| Arc::ptr_eq(d, lib)) {
                distinct.push(lib);
            }
        }
        let resolved = distinct
            .into_iter()
            .find(|lib| {
                pattern::library_fit(mat, kh, kw, cin, cols, entries, lib)
                    >= LIBRARY_FIT_THRESHOLD
            })
            .cloned()
            .unwrap_or_else(|| {
                Arc::new(pattern::select_pattern_library(
                    mat,
                    kh,
                    kw,
                    cin,
                    cols,
                    entries,
                    pattern::DEFAULT_LIBRARY,
                ))
            });
        libs.push((fp, resolved.clone()));
        resolved
    }

    /// Attach a plan database: [`PlanCache::plan_node`] now answers from
    /// it when it can and records every cold search into it. Call
    /// [`PlanCache::save_db`] after the build to persist.
    pub fn attach_db(&mut self, db: db::PlanDb) {
        self.db = Some(db);
    }

    /// Enable measured (beam-timed) search — `cadnn plan --tune`.
    pub fn set_tune(&mut self, tune: bool) {
        self.tune = tune;
    }

    pub fn db(&self) -> Option<&db::PlanDb> {
        self.db.as_ref()
    }

    pub fn db_mut(&mut self) -> Option<&mut db::PlanDb> {
        self.db.as_mut()
    }

    /// Whether [`PlanCache::plan_node`] runs the search (a database is
    /// attached or tuning is on) instead of the legacy planners.
    pub fn searching(&self) -> bool {
        self.db.is_some() || self.tune
    }

    /// This build's planning counters so far.
    pub fn tune_stats(&self) -> db::TuneStats {
        self.stats
    }

    /// Persist the attached database, if any (no-op otherwise).
    pub fn save_db(&mut self) -> Result<(), String> {
        match self.db.as_mut() {
            Some(d) => d.save(),
            None => Ok(()),
        }
    }

    /// Plan one pruned layer — the instance build's single entry point,
    /// arbitrating (in order): the in-process spec memo, the attached
    /// [`db::PlanDb`] (exact spec + current generation), the
    /// [`search`] module (when a database is attached or `tune` is on),
    /// and the legacy measured/heuristic planners. `measure` is the
    /// caller's tuner flag — with the search engaged it (or `tune`)
    /// turns on beam measurement; cold results are recorded back into
    /// the database ranked best-first. The returned plan has
    /// `rows_per_image = 0`; the caller owns that field (it is the one
    /// axis that legitimately differs across batch variants of the same
    /// spec).
    #[allow(clippy::too_many_arguments)]
    pub fn plan_node(
        &mut self,
        name: &str,
        policy: FormatPolicy,
        value_policy: ValuePolicy,
        declared: Option<u8>,
        csr: &CsrMatrix,
        m: usize,
        hwio: [usize; 4],
        measure: bool,
    ) -> LayerPlan {
        self.stats.requests += 1;
        let device_fp = self.db.as_ref().map(|d| d.device_fp()).unwrap_or(0);
        let spec = db::SpecKey::from_layer(policy, value_policy, declared, csr, hwio,
            device_fp);
        if let Some(lp) = self.memo.get(&spec) {
            self.stats.memo_hits += 1;
            return lp.clone();
        }
        if let Some(d) = self.db.as_mut() {
            if let Some(lp) = d.best_plan(&spec) {
                self.stats.db_hits += 1;
                self.memo.insert(spec, lp.clone());
                return lp;
            }
        }
        self.stats.searched += 1;
        let lp = if self.searching() {
            let do_measure = measure || self.tune;
            let (table, seeds) = match self.db.as_ref() {
                Some(d) => (d.current_table().clone(), d.seed_plans(&spec)),
                None => (db::CostTable::builtin(), Vec::new()),
            };
            let mm_seed = spec.seed();
            let arts = self.layer(name, csr);
            let out = search::search_layer(
                policy,
                value_policy,
                declared,
                csr,
                m,
                hwio,
                &table,
                &seeds,
                do_measure,
                mm_seed,
                arts,
            );
            self.stats.measurements += out.measurements;
            let lp = out.best().map(|c| c.plan.clone()).unwrap_or_else(LayerPlan::csr);
            if let Some(d) = self.db.as_mut() {
                let prov = if do_measure { db::Provenance::Measured } else {
                    db::Provenance::Modeled };
                d.insert(spec, out.candidates, prov);
            }
            lp
        } else if measure {
            self.stats.measurements += measured_candidate_count(policy, csr, hwio);
            let arts = self.layer(name, csr);
            plan_layer_measured_valued(policy, value_policy, declared, csr, m, hwio, arts)
        } else {
            let arts = self.layer(name, csr);
            plan_layer_valued(policy, value_policy, declared, csr, m, hwio, arts)
        };
        self.memo.insert(spec, lp.clone());
        lp
    }
}

/// How many kernel timings [`plan_layer_measured_valued`] runs for a
/// layer: CSR + dense + the BSR candidates + Pattern where eligible
/// (Auto only — pinned policies and degenerate layers skip measurement).
fn measured_candidate_count(policy: FormatPolicy, csr: &CsrMatrix, hwio: [usize; 4]) -> usize {
    if policy != FormatPolicy::Auto || csr.nnz() == 0 || csr.rows == 0 || csr.cols == 0 {
        return 0;
    }
    2 + BSR_CANDIDATES.len() + usize::from(pattern_eligible(csr, hwio))
}

/// Per-row execution cost (units) of a layer under `lp`'s format and
/// value width — the `cost_per_row` every planned [`LayerPlan`]
/// carries. Quantized payloads scale the sparse-kernel estimates by
/// [`lut_cost_factor`] (Dense is always f32), so serving-cost estimates
/// stay honest when a codebook payload rides a LUT kernel.
fn unit_cost(lp: &LayerPlan, csr: &CsrMatrix, hwio: [usize; 4], arts: &mut LayerArtifacts) -> f64 {
    let lut = lut_cost_factor(lp.value_bits);
    match lp.format {
        SparseFormat::Dense => (csr.rows * csr.cols) as f64 * COST_DENSE_MAC,
        SparseFormat::Csr => csr.nnz() as f64 * COST_CSR_NNZ * lut,
        SparseFormat::Bsr { br, bc } => {
            let (blocks, _) = arts.blocks_for(csr, br, bc);
            (blocks * br * bc) as f64 * bsr_cost(br, bc) * lut
        }
        SparseFormat::Pattern => {
            csr.nnz() as f64 * COST_PATTERN_VAL * lut
                + pattern::count_kernels(csr, hwio[2]) as f64 * COST_PATTERN_KERNEL
        }
    }
}

/// Heuristic per-layer format choice. `m` is the GEMM row count the layer
/// runs at (batch * output pixels); `hwio` is the conv weight shape
/// `[kh, kw, cin, cout]` — spatial kernels (kh*kw > 1) run through
/// im2col, so Auto demands a stricter win before leaving the CSR
/// baseline for those. Spatial kernels are also where the Pattern
/// challenger enters (see [`pattern_eligible`]).
///
/// # Examples
///
/// ```
/// use cadnn::compress::csr::CsrMatrix;
/// use cadnn::compress::pattern::prune_patterns;
/// use cadnn::planner::{choose, FormatPolicy, SparseFormat};
///
/// // a pattern-pruned 3x3 conv layer: Auto must pick the pattern format
/// let (kh, kw, cin, cout) = (3, 3, 8, 32);
/// let mut w: Vec<f32> = (0..kh * kw * cin * cout)
///     .map(|i| ((i * 2654435761usize) % 1000) as f32 / 1000.0 + 0.001)
///     .collect();
/// prune_patterns(&mut w, kh, kw, cin, cout, 0.8, 4, 8);
/// let csr = CsrMatrix::from_dense(&w, kh * kw * cin, cout);
/// let plan = choose(FormatPolicy::Auto, &csr, 196, [kh, kw, cin, cout]);
/// assert_eq!(plan.format, SparseFormat::Pattern);
/// ```
pub fn choose(policy: FormatPolicy, csr: &CsrMatrix, m: usize, hwio: [usize; 4]) -> LayerPlan {
    plan_layer(policy, csr, m, hwio, &mut LayerArtifacts::default())
}

/// [`choose`] with memoized per-layer artifacts: the instance build
/// passes the layer's [`PlanCache`] slot so block counts, the clustering
/// permutation, and the densified matrix are computed once per pruned
/// layer and shared with the payload rewrite (and later batch variants).
/// Fills the plan's `cost_per_row`; the caller owns `rows_per_image`
/// (the planner cannot know the batch size behind `m`).
pub fn plan_layer(
    policy: FormatPolicy,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    arts: &mut LayerArtifacts,
) -> LayerPlan {
    plan_layer_valued(policy, ValuePolicy::Auto, None, csr, m, hwio, arts)
}

/// [`plan_layer`] with the value-precision axis: `value_policy` is the
/// engine-level knob (`EngineBuilder::value_bits`), `declared` the
/// codebook width the layer's compress report exported
/// (`SparsityProfile::quant_bits`) — [`resolve_value_bits`] combines
/// them with the chosen format, and the plan's `cost_per_row` prices
/// the LUT kernel via [`lut_cost_factor`].
#[allow(clippy::too_many_arguments)]
pub fn plan_layer_valued(
    policy: FormatPolicy,
    value_policy: ValuePolicy,
    declared: Option<u8>,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    arts: &mut LayerArtifacts,
) -> LayerPlan {
    let mut lp = choose_impl(policy, csr, m, hwio, arts);
    lp.value_bits = resolve_value_bits(value_policy, declared, lp.format);
    lp.cost_per_row = unit_cost(&lp, csr, hwio, arts);
    lp
}

fn choose_impl(
    policy: FormatPolicy,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    arts: &mut LayerArtifacts,
) -> LayerPlan {
    debug_assert_eq!(csr.rows, hwio[0] * hwio[1] * hwio[2], "hwio inconsistent with K");
    debug_assert_eq!(csr.cols, hwio[3], "hwio inconsistent with N");
    match policy {
        FormatPolicy::Csr => LayerPlan::csr(),
        FormatPolicy::Pattern => {
            if pattern_eligible(csr, hwio) && csr.nnz() > 0 {
                LayerPlan::with_format(SparseFormat::Pattern, false)
            } else {
                LayerPlan::csr()
            }
        }
        FormatPolicy::Bsr => {
            // best-filling candidate, fill traded by per-value cost
            let mut best = None;
            for (br, bc, cost) in BSR_CANDIDATES {
                let (blocks, reorder_on) = arts.blocks_for(csr, br, bc);
                let est = (blocks * br * bc) as f64 * cost;
                if best.as_ref().map(|(e, _)| est < *e).unwrap_or(true) {
                    best = Some((
                        est,
                        LayerPlan::with_format(SparseFormat::Bsr { br, bc }, reorder_on),
                    ));
                }
            }
            best.map(|(_, lp)| lp).unwrap_or_else(LayerPlan::csr)
        }
        FormatPolicy::Auto => {
            let nnz = csr.nnz();
            if nnz == 0 {
                return LayerPlan::csr();
            }
            let mf = m.max(1) as f64;
            let est_csr = mf * nnz as f64 * COST_CSR_NNZ;
            let spatial = hwio[0] * hwio[1] > 1;
            let margin = if spatial { SPATIAL_SWITCH_MARGIN } else { AUTO_SWITCH_MARGIN };
            // a challenger must beat the *discounted* CSR estimate; after
            // that, challengers compete on raw estimates
            let mut best = LayerPlan::csr();
            let mut best_est = est_csr * margin;
            let est_dense = mf * (csr.rows * csr.cols) as f64 * COST_DENSE_MAC;
            if est_dense < best_est {
                best = LayerPlan::with_format(SparseFormat::Dense, false);
                best_est = est_dense;
            }
            for (br, bc, cost) in BSR_CANDIDATES {
                let (blocks, reorder_on) = arts.blocks_for(csr, br, bc);
                let est = mf * (blocks * br * bc) as f64 * cost;
                if est < best_est {
                    best = LayerPlan::with_format(SparseFormat::Bsr { br, bc }, reorder_on);
                    best_est = est;
                }
            }
            if pattern_eligible(csr, hwio) {
                let kernels = pattern::count_kernels(csr, hwio[2]);
                let est = mf
                    * (nnz as f64 * COST_PATTERN_VAL + kernels as f64 * COST_PATTERN_KERNEL);
                if est < best_est {
                    best = LayerPlan::with_format(SparseFormat::Pattern, false);
                }
            }
            best
        }
    }
}

// ---------------------------------------------------------------------------
// Measured refinement (tuner mode)
// ---------------------------------------------------------------------------

/// Approximate thread-pool dispatch overhead (µs) used to refine the
/// serial→parallel cutover from a measured serial time.
pub const PARALLEL_DISPATCH_US: f64 = 30.0;

/// Rows measured per candidate (capped so tuning a ResNet-50 stays in
/// the same budget class as the tile tuner).
const MEASURE_M_CAP: usize = 256;
/// Per-candidate measurement budget (µs), matching the tile tuner's
/// adaptive loop scale.
const MEASURE_BUDGET_US: f64 = 2_000.0;

fn measure_us<F: FnMut()>(f: F) -> f64 {
    let samples = stats::measure_adaptive_us(MEASURE_BUDGET_US, 6, f);
    stats::Summary::from(&samples).map(|s| s.p50).unwrap_or(f64::MAX)
}

/// Measured per-layer choice: time the heuristic shortlist (CSR, dense,
/// both BSR candidates, Pattern where eligible) with the real serial
/// kernels on the layer's own weights, then pick the winner — CSR keeps
/// ties. Also refines the layer's parallel cutover from the measured
/// per-row cost: cheap layers need more rows before the pool dispatch
/// amortizes. The measurement inputs are seeded from the layer's own
/// spec-key hash ([`db::spec_seed`]), so identical specs resolve
/// identically across builds and processes.
pub fn choose_measured(
    policy: FormatPolicy,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
) -> LayerPlan {
    plan_layer_measured(policy, csr, m, hwio, &mut LayerArtifacts::default())
}

/// [`choose_measured`] with memoized per-layer artifacts (densification
/// and clustering shared with the heuristic estimate, the payload
/// rewrite, and later batch variants). Fills `cost_per_row` from the
/// heuristic unit model (the measured times pick the format; the cost
/// units stay comparable across layers and batch sizes).
pub fn plan_layer_measured(
    policy: FormatPolicy,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    arts: &mut LayerArtifacts,
) -> LayerPlan {
    plan_layer_measured_valued(policy, ValuePolicy::Auto, None, csr, m, hwio, arts)
}

/// [`plan_layer_measured`] with the value-precision axis. The measured
/// times pick the *format* (value width doesn't change which kernel
/// family wins — the LUT factors are within a few percent); the
/// resolved `value_bits` then scales `cost_per_row` through
/// [`lut_cost_factor`], exactly as the heuristic mode does, so measured
/// and heuristic plans price quantized payloads consistently.
#[allow(clippy::too_many_arguments)]
pub fn plan_layer_measured_valued(
    policy: FormatPolicy,
    value_policy: ValuePolicy,
    declared: Option<u8>,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    arts: &mut LayerArtifacts,
) -> LayerPlan {
    if policy != FormatPolicy::Auto {
        return plan_layer_valued(policy, value_policy, declared, csr, m, hwio, arts);
    }
    let (k, n) = (csr.rows, csr.cols);
    if csr.nnz() == 0 || k == 0 || n == 0 {
        return LayerPlan::csr();
    }
    let mm = m.clamp(1, MEASURE_M_CAP);
    // deterministic per spec, not per caller: identical specs measure on
    // identical inputs across builds and processes
    let seed = db::spec_seed(policy, value_policy, declared, csr, hwio);
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut a = vec![0.0f32; mm * k];
    rng.fill_normal(&mut a, 0.5);
    let mut c = vec![0.0f32; mm * n];

    let t_csr = measure_us(|| {
        crate::kernels::sparse::csr_gemm(&a, csr, &mut c, mm, &Epilogue::None);
    });
    let mut best = LayerPlan::csr();
    let mut best_us = t_csr * 0.98; // CSR keeps ties

    let dense = arts.dense(csr);
    let t_dense = measure_us(|| {
        crate::kernels::gemm::gemm_blocked(
            &a,
            &dense,
            &mut c,
            mm,
            k,
            n,
            &TileConfig::DEFAULT,
            &Epilogue::None,
        );
    });
    if t_dense < best_us {
        best = LayerPlan::with_format(SparseFormat::Dense, false);
        best_us = t_dense;
    }

    for (br, bc, _) in BSR_CANDIDATES {
        let (_, reorder_on) = arts.blocks_for(csr, br, bc);
        let mat = if reorder_on {
            let perm = arts.permutation(csr, br).clone();
            let permuted = reorder::permute_cols(&dense, k, n, &perm);
            BsrMatrix::from_dense(&permuted, k, n, br, bc)
        } else {
            BsrMatrix::from_dense(&dense, k, n, br, bc)
        };
        let t = measure_us(|| {
            crate::kernels::bsr::bsr_gemm(&a, &mat, &mut c, mm, &Epilogue::None);
        });
        if t < best_us {
            best = LayerPlan::with_format(SparseFormat::Bsr { br, bc }, reorder_on);
            best_us = t;
        }
    }

    if pattern_eligible(csr, hwio) {
        let mat = PatternMatrix::from_dense(&dense, hwio[0], hwio[1], hwio[2], n);
        let t = measure_us(|| {
            crate::kernels::pattern::pattern_gemm(&a, &mat, &mut c, mm, &Epilogue::None);
        });
        if t < best_us {
            best = LayerPlan::with_format(SparseFormat::Pattern, false);
            best_us = t;
        }
    }

    // cutover refinement: rows needed before the pool dispatch amortizes
    // to <50% overhead at the measured per-row cost
    let per_row_us = (best_us.max(1e-3)) / mm as f64;
    let amortize_rows = (2.0 * PARALLEL_DISPATCH_US / per_row_us).ceil() as usize;
    best.parallel_cutover = amortize_rows.max(PARALLEL_M_CUTOVER);
    best.value_bits = resolve_value_bits(value_policy, declared, best.format);
    best.cost_per_row = unit_cost(&best, csr, hwio, arts);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(k: usize, n: usize, density: f64, seed: u64) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; k * n];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        CsrMatrix::from_dense(&dense, k, n)
    }

    /// Whole (br x bc)-aligned blocks survive, everything else pruned —
    /// the structured sparsity BSR exists for.
    fn block_structured_csr(
        k: usize,
        n: usize,
        br: usize,
        bc: usize,
        keep: f64,
        seed: u64,
    ) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; k * n];
        for b in 0..k.div_ceil(br) {
            for j in 0..n.div_ceil(bc) {
                if rng.f64() >= keep {
                    continue;
                }
                for p in 0..br.min(k - b * br) {
                    for x in 0..bc.min(n - j * bc) {
                        dense[(b * br + p) * n + j * bc + x] = rng.normal() as f32;
                    }
                }
            }
        }
        CsrMatrix::from_dense(&dense, k, n)
    }

    fn gemm_hwio(k: usize, n: usize) -> [usize; 4] {
        [1, 1, k, n]
    }

    #[test]
    fn format_labels_roundtrip() {
        for f in [
            SparseFormat::Dense,
            SparseFormat::Csr,
            SparseFormat::Bsr { br: 4, bc: 1 },
            SparseFormat::Bsr { br: 4, bc: 4 },
            SparseFormat::Pattern,
        ] {
            assert_eq!(SparseFormat::parse(&f.label()), Some(f));
        }
        assert_eq!(SparseFormat::parse("bsrXxY"), None);
        assert_eq!(SparseFormat::parse("bsr0x4"), None);
        assert_eq!(SparseFormat::parse("coo"), None);
    }

    /// Pattern-pruned 3x3 conv weights (the PatDNN regime): Auto must
    /// leave the CSR baseline for the pattern format, and a pinned
    /// Pattern policy must do the same.
    #[test]
    fn auto_picks_pattern_on_pattern_pruned_weights() {
        let (kh, kw, cin, cout) = (3usize, 3usize, 8usize, 32usize);
        let mut rng = Rng::new(21);
        let mut w = vec![0.0f32; kh * kw * cin * cout];
        rng.fill_normal(&mut w, 0.5);
        crate::compress::pattern::prune_patterns(&mut w, kh, kw, cin, cout, 0.8, 4, 8);
        let csr = CsrMatrix::from_dense(&w, kh * kw * cin, cout);
        let hwio = [kh, kw, cin, cout];
        let auto = choose(FormatPolicy::Auto, &csr, 196, hwio);
        assert_eq!(auto.format, SparseFormat::Pattern, "{auto:?}");
        assert!(!auto.reorder, "pattern plans carry no column permutation");
        let pinned = choose(FormatPolicy::Pattern, &csr, 196, hwio);
        assert_eq!(pinned.format, SparseFormat::Pattern);
    }

    /// The pattern format never applies to 1x1 (GEMM-shaped) layers or
    /// kernels beyond the table ceiling; pinning Pattern there falls back
    /// to the CSR baseline instead of failing.
    #[test]
    fn pattern_policy_falls_back_off_spatial() {
        let csr = random_csr(128, 64, 0.2, 6);
        let gemm = choose(FormatPolicy::Pattern, &csr, 196, gemm_hwio(128, 64));
        assert_eq!(gemm.format, SparseFormat::Csr, "{gemm:?}");
        // 5x5 kernels: 25 positions exceed the u16-id table ceiling
        let csr5 = random_csr(25 * 4, 16, 0.2, 7);
        let conv5 = choose(FormatPolicy::Pattern, &csr5, 196, [5, 5, 4, 16]);
        assert_eq!(conv5.format, SparseFormat::Csr, "{conv5:?}");
        let auto = choose(FormatPolicy::Auto, &csr, 196, gemm_hwio(128, 64));
        assert_ne!(auto.format, SparseFormat::Pattern, "{auto:?}");
    }

    /// Scattered magnitude pruning leaves too few entries per kernel for
    /// the per-kernel overhead to amortize: Auto keeps CSR.
    #[test]
    fn auto_keeps_csr_on_scattered_spatial_pruning() {
        let csr = random_csr(9 * 16, 64, 0.08, 8);
        let lp = choose(FormatPolicy::Auto, &csr, 196, [3, 3, 16, 64]);
        assert_eq!(lp.format, SparseFormat::Csr, "{lp:?}");
    }

    #[test]
    fn auto_keeps_csr_on_scattered_low_density() {
        let csr = random_csr(128, 64, 0.08, 1);
        let lp = choose(FormatPolicy::Auto, &csr, 196, gemm_hwio(128, 64));
        assert_eq!(lp.format, SparseFormat::Csr, "{lp:?}");
    }

    #[test]
    fn auto_goes_dense_when_pruning_is_shallow() {
        let csr = random_csr(128, 64, 0.6, 2);
        let lp = choose(FormatPolicy::Auto, &csr, 196, gemm_hwio(128, 64));
        assert_eq!(lp.format, SparseFormat::Dense, "{lp:?}");
    }

    #[test]
    fn auto_picks_bsr_on_block_structure() {
        let csr = block_structured_csr(128, 64, 4, 4, 0.3, 3);
        let lp = choose(FormatPolicy::Auto, &csr, 196, gemm_hwio(128, 64));
        assert!(
            matches!(lp.format, SparseFormat::Bsr { .. }),
            "block-aligned sparsity must choose BSR, got {lp:?}"
        );
    }

    #[test]
    fn policies_pin_formats() {
        let csr = random_csr(64, 32, 0.1, 4);
        let hwio = gemm_hwio(64, 32);
        assert_eq!(choose(FormatPolicy::Csr, &csr, 64, hwio).format, SparseFormat::Csr);
        assert!(matches!(
            choose(FormatPolicy::Bsr, &csr, 64, hwio).format,
            SparseFormat::Bsr { .. }
        ));
    }

    #[test]
    fn spatial_layers_need_a_bigger_win() {
        // density between the GEMM boundary (COST_DENSE_MAC / 0.85 =
        // 0.176) and the spatial boundary (0.15 / 0.75 = 0.20): a 1x1
        // (GEMM) layer flips to Dense, the same matrix as a 3x3 conv
        // stays CSR
        let csr = random_csr(288, 128, 0.19, 5);
        let gemm = choose(FormatPolicy::Auto, &csr, 196, [1, 1, 288, 128]);
        let conv = choose(FormatPolicy::Auto, &csr, 196, [3, 3, 32, 128]);
        assert_eq!(gemm.format, SparseFormat::Dense, "{gemm:?}");
        assert_eq!(conv.format, SparseFormat::Csr, "{conv:?}");
    }

    #[test]
    fn exec_plan_json_roundtrip() {
        let mut plan = ExecPlan::default();
        plan.layers.insert("c1".into(), LayerPlan::csr());
        plan.layers.insert(
            "c2".into(),
            LayerPlan {
                format: SparseFormat::Bsr { br: 4, bc: 4 },
                value_bits: ValueBits::Q4,
                reorder: true,
                parallel_cutover: 256,
                cost_per_row: 172.8,
                rows_per_image: 196,
            },
        );
        let text = plan.to_json().to_string_pretty();
        let parsed = ExecPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, plan);
    }

    /// Old manifests carry no `value_bits`: plans load as f32 payloads;
    /// an unknown width rejects the plan like an unknown format does.
    #[test]
    fn value_bits_json_fallback_and_rejection() {
        let j = Json::parse(r#"{"layers": {"c1": {"format": "pattern"}}}"#).unwrap();
        let p = ExecPlan::from_json(&j).unwrap();
        assert_eq!(p.get("c1").unwrap().value_bits, ValueBits::F32);
        let j = Json::parse(r#"{"layers": {"c1": {"format": "csr", "value_bits": 4}}}"#).unwrap();
        assert_eq!(
            ExecPlan::from_json(&j).unwrap().get("c1").unwrap().value_bits,
            ValueBits::Q4
        );
        let j = Json::parse(r#"{"layers": {"c1": {"format": "csr", "value_bits": 16}}}"#).unwrap();
        assert!(ExecPlan::from_json(&j).is_none(), "unknown width must reject the plan");
    }

    /// The value axis is orthogonal to the format axis: the policy and
    /// the declared codebook resolve per format, Dense never quantizes,
    /// and quantized plans carry the LUT-scaled cost.
    #[test]
    fn value_policy_resolution_and_lut_costs() {
        use crate::compress::qsparse::ValueBits as VB;
        let pat = SparseFormat::Pattern;
        assert_eq!(resolve_value_bits(ValuePolicy::F32, Some(4), pat), VB::F32);
        assert_eq!(resolve_value_bits(ValuePolicy::Q8, None, pat), VB::Q8);
        assert_eq!(resolve_value_bits(ValuePolicy::Q4, None, pat), VB::Q4);
        assert_eq!(resolve_value_bits(ValuePolicy::Auto, None, pat), VB::F32);
        assert_eq!(resolve_value_bits(ValuePolicy::Auto, Some(4), pat), VB::Q4);
        assert_eq!(resolve_value_bits(ValuePolicy::Auto, Some(8), pat), VB::Q8);
        assert_eq!(
            resolve_value_bits(ValuePolicy::Q4, Some(4), SparseFormat::Dense),
            VB::F32,
            "dense payloads never quantize"
        );

        // plan_layer_valued: the declared codebook reaches the plan and
        // scales cost_per_row by the LUT factor
        let csr = random_csr(128, 64, 0.08, 1);
        let hwio = gemm_hwio(128, 64);
        let mut arts = LayerArtifacts::default();
        let f32_lp =
            plan_layer_valued(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, 196, hwio,
                &mut arts);
        assert_eq!(f32_lp.format, SparseFormat::Csr);
        assert_eq!(f32_lp.value_bits, VB::F32);
        let q4_lp = plan_layer_valued(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            Some(4),
            &csr,
            196,
            hwio,
            &mut arts,
        );
        assert_eq!(q4_lp.format, SparseFormat::Csr, "value axis must not change the format");
        assert_eq!(q4_lp.value_bits, VB::Q4);
        assert!(
            (q4_lp.cost_per_row - f32_lp.cost_per_row * COST_LUT_Q4).abs() < 1e-9,
            "q4 cost {} vs f32 {} * {}",
            q4_lp.cost_per_row,
            f32_lp.cost_per_row,
            COST_LUT_Q4
        );
    }

    /// The PR-4 aliasing regression: two same-(kh, kw, cin) layers with
    /// disjoint magnitude layouts must NOT share one pattern library —
    /// the fit check re-selects for the second layer — while the same
    /// weights (batch variants) and genuinely similar layers still hit
    /// the cache.
    #[test]
    fn pattern_library_cache_respects_fit() {
        let (kh, kw, cin, cols) = (3usize, 3usize, 2usize, 8usize);
        let kk = kh * kw;
        // layer A: all energy on even positions; layer B: odd positions
        let fill = |positions: &[usize]| {
            let mut m = vec![0.0f32; kk * cin * cols];
            for ci in 0..cin {
                for co in 0..cols {
                    for (rank, &pos) in positions.iter().enumerate() {
                        m[(pos * cin + ci) * cols + co] = 2.0 - 0.1 * rank as f32;
                    }
                }
            }
            m
        };
        let a = fill(&[0, 2, 4, 6]);
        let b = fill(&[1, 3, 5, 7]);
        let mut cache = PlanCache::default();
        let lib_a = cache.pattern_library(kh, kw, cin, 4, cols, &a);
        assert!(
            pattern::library_fit(&a, kh, kw, cin, cols, 4, &lib_a) > 0.99,
            "own library must fit its own weights"
        );
        // same weights again (another batch variant): cache hit
        let lib_a2 = cache.pattern_library(kh, kw, cin, 4, cols, &a);
        assert!(Arc::ptr_eq(&lib_a, &lib_a2), "identical weights must reuse the library");
        // disjoint layout: must re-select, and the new library must fit
        assert!(
            pattern::library_fit(&b, kh, kw, cin, cols, 4, &lib_a) < LIBRARY_FIT_THRESHOLD,
            "the regression precondition: A's library does not fit B"
        );
        let lib_b = cache.pattern_library(kh, kw, cin, 4, cols, &b);
        assert!(!Arc::ptr_eq(&lib_a, &lib_b), "aliasing regression: B reused A's library");
        assert!(pattern::library_fit(&b, kh, kw, cin, cols, 4, &lib_b) > 0.99);
        // interleaved revisits resolve by exact fingerprint, not scan
        // order — A still gets A's library after B entered the family
        let lib_a3 = cache.pattern_library(kh, kw, cin, 4, cols, &a);
        assert!(Arc::ptr_eq(&lib_a, &lib_a3), "fingerprint memo must survive new entries");
        // and pruning B with its own library keeps B's positions
        let mut pruned = b.clone();
        pattern::prune_with_library(&mut pruned, kh, kw, cin, cols, 0.6, 4, &lib_b);
        let kept_positions: Vec<usize> = (0..kk)
            .filter(|&pos| (0..cin * cols).any(|kn| {
                let (ci, co) = (kn / cols, kn % cols);
                pruned[(pos * cin + ci) * cols + co] != 0.0
            }))
            .collect();
        assert!(
            kept_positions.iter().all(|p| p % 2 == 1),
            "B must keep its own (odd) positions, kept {kept_positions:?}"
        );
    }

    #[test]
    fn plan_costs_are_batch_aware() {
        let mut plan = ExecPlan::default();
        // no cost info -> no cost model
        plan.layers.insert("c1".into(), LayerPlan::csr());
        assert_eq!(plan.cost_at(4), None);
        assert_eq!(BatchCost::from_plan(&plan), None);
        // per-layer costs compose into an affine batch cost
        plan.layers.insert(
            "c2".into(),
            LayerPlan { cost_per_row: 10.0, rows_per_image: 50, ..LayerPlan::csr() },
        );
        plan.layers.insert(
            "c3".into(),
            LayerPlan { cost_per_row: 2.0, rows_per_image: 100, ..LayerPlan::csr() },
        );
        assert_eq!(plan.per_image_cost(), 700.0);
        let c = BatchCost::from_plan(&plan).unwrap();
        assert_eq!(c.cost_at(1), COST_BATCH_OVERHEAD + 700.0);
        assert_eq!(c.cost_at(8), COST_BATCH_OVERHEAD + 8.0 * 700.0);
        // per-image cost shrinks with m: the overhead amortizes
        assert!(c.cost_at(8) / 8.0 < c.cost_at(1));
        assert_eq!(plan.cost_at(8), Some(c.cost_at(8)));
    }

    #[test]
    fn capacity_math_is_pinned() {
        // 1000 + 1000·m units at 1 µs/unit: batch 1 runs in 2000µs,
        // batch 8 in 9000µs
        let c = BatchCost { per_image: 1_000.0, overhead: 1_000.0 };
        assert_eq!(c.est_us(1, 1.0), 2_000.0);
        assert_eq!(c.est_us(8, 1.0), 9_000.0);
        assert_eq!(c.est_us(8, 0.5), 4_500.0);
        // capacity: 1 image / 2000µs = 500/s; 8 images / 9000µs ≈ 888.9/s
        assert_eq!(c.capacity_rps(1, 1.0), 500.0);
        assert_eq!(c.capacity_rps(8, 1.0), 8.0 * 1e6 / 9_000.0);
        // batching always raises capacity under an affine cost
        assert!(c.capacity_rps(8, 1.0) > c.capacity_rps(1, 1.0));
        // degenerate scales and batch 0 are safe zeros, never NaN/inf
        assert_eq!(c.est_us(4, 0.0), 0.0);
        assert_eq!(c.capacity_rps(4, 0.0), 0.0);
        assert_eq!(c.capacity_rps(0, 1.0), 0.0);
        assert_eq!(c.capacity_rps(4, f64::NAN), 0.0);
    }

    /// Planned layers carry a positive `cost_per_row` matching the
    /// heuristic unit model for the chosen format.
    #[test]
    fn plans_carry_unit_costs() {
        let csr = random_csr(128, 64, 0.08, 1);
        let lp = choose(FormatPolicy::Auto, &csr, 196, gemm_hwio(128, 64));
        assert_eq!(lp.format, SparseFormat::Csr);
        assert_eq!(lp.cost_per_row, csr.nnz() as f64 * COST_CSR_NNZ);
        let dense_lp = choose(FormatPolicy::Auto, &random_csr(128, 64, 0.6, 2), 196,
            gemm_hwio(128, 64));
        assert_eq!(dense_lp.format, SparseFormat::Dense);
        assert_eq!(dense_lp.cost_per_row, (128 * 64) as f64 * COST_DENSE_MAC);
    }

    /// The memoized artifacts agree with the uncached entry points and
    /// only compute clustering once.
    #[test]
    fn layer_artifacts_match_uncached_choice() {
        let csr = block_structured_csr(128, 64, 4, 4, 0.3, 3);
        let hwio = gemm_hwio(128, 64);
        let mut arts = LayerArtifacts::default();
        let cached = plan_layer(FormatPolicy::Auto, &csr, 196, hwio, &mut arts);
        let plain = choose(FormatPolicy::Auto, &csr, 196, hwio);
        assert_eq!(cached, plain);
        // a second pass hits the memo and yields the identical plan
        let again = plan_layer(FormatPolicy::Auto, &csr, 196, hwio, &mut arts);
        assert_eq!(again, plain);
        // the cached permutation is the same one the estimate used
        let p = arts.permutation(&csr, 4).clone();
        assert_eq!(p, reorder::cluster_columns_csr(&csr, 4));
        // the cache guards against stale entries for a different matrix
        let mut cache = PlanCache::default();
        cache.layer("c1", &csr).permutation(&csr, 4);
        let other = random_csr(64, 32, 0.2, 9);
        let slot = cache.layer("c1", &other);
        assert!(slot.perms.is_empty(), "stale artifacts must reset");
        // ...including a same-shape, same-nnz matrix with different
        // values (the collision the density-exact cut makes easy): the
        // content fingerprint must reset the slot
        cache.layer("c2", &csr).permutation(&csr, 4);
        let mut perturbed = csr.clone();
        for v in perturbed.values.iter_mut() {
            *v += 1.0;
        }
        assert_eq!((perturbed.rows, perturbed.cols, perturbed.nnz()), (csr.rows, csr.cols,
            csr.nnz()));
        let slot = cache.layer("c2", &perturbed);
        assert!(slot.perms.is_empty(), "value change must invalidate the slot");
        // and an identical matrix keeps the memo
        cache.layer("c3", &csr).permutation(&csr, 4);
        assert!(!cache.layer("c3", &csr).perms.is_empty(), "identical matrix must hit");
    }

    #[test]
    fn malformed_plan_json_is_none() {
        for src in [
            r#"{"no_layers": {}}"#,
            r#"{"layers": {"c1": {"format": "coo"}}}"#,
            r#"{"layers": {"c1": {}}}"#,
        ] {
            let j = Json::parse(src).unwrap();
            assert!(ExecPlan::from_json(&j).is_none(), "{src}");
        }
        // defaults fill in optional fields
        let j = Json::parse(r#"{"layers": {"c1": {"format": "bsr4x1"}}}"#).unwrap();
        let p = ExecPlan::from_json(&j).unwrap();
        let lp = p.get("c1").unwrap();
        assert_eq!(lp.format, SparseFormat::Bsr { br: 4, bc: 1 });
        assert!(!lp.reorder);
        assert_eq!(lp.parallel_cutover, PARALLEL_M_CUTOVER);
    }

    #[test]
    fn measured_mode_returns_a_shortlist_member() {
        let csr = random_csr(48, 24, 0.25, 7);
        let lp = choose_measured(FormatPolicy::Auto, &csr, 64, gemm_hwio(48, 24));
        assert!(lp.parallel_cutover >= PARALLEL_M_CUTOVER);
        assert!(matches!(
            lp.format,
            SparseFormat::Csr
                | SparseFormat::Dense
                | SparseFormat::Bsr { .. }
                | SparseFormat::Pattern
        ));
    }

    /// Without a database or tuning, `plan_node` is the heuristic
    /// planner plus the spec memo: batch variants of one layer (same
    /// csr, different m) plan once and identically.
    #[test]
    fn plan_node_memoizes_across_batch_variants() {
        let csr = random_csr(96, 48, 0.1, 13);
        let hwio = gemm_hwio(96, 48);
        let mut cache = PlanCache::default();
        let lp1 = cache.plan_node("c1", FormatPolicy::Auto, ValuePolicy::Auto, None, &csr,
            196, hwio, false);
        let lp4 = cache.plan_node("c1", FormatPolicy::Auto, ValuePolicy::Auto, None, &csr,
            4 * 196, hwio, false);
        assert_eq!(lp1, lp4, "batch variants must share one plan");
        let direct = plan_layer_valued(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            196,
            hwio,
            &mut LayerArtifacts::default(),
        );
        assert_eq!(lp1, direct, "no-db plan_node must equal the heuristic planner");
        let st = cache.tune_stats();
        assert_eq!((st.requests, st.memo_hits, st.searched), (2, 1, 1));
        assert_eq!(st.measurements, 0);
        // a different value policy is a different spec
        let q8 = cache.plan_node("c1", FormatPolicy::Auto, ValuePolicy::Q8, None, &csr, 196,
            hwio, false);
        assert_eq!(q8.value_bits, ValueBits::Q8);
        assert_eq!(cache.tune_stats().searched, 2);
    }

    /// With an in-memory database attached, the first build populates it
    /// and the second answers every request from it — zero searches,
    /// zero measurements, identical plans (the warm-replan contract in
    /// miniature; `rust/tests/plan_db.rs` proves it end-to-end).
    #[test]
    fn plan_node_warm_db_answers_without_searching() {
        let csrs: Vec<CsrMatrix> =
            (0..4).map(|i| random_csr(64 + 8 * i, 32, 0.1 + 0.1 * i as f64, 40 + i as
                u64)).collect();
        let mut cold = PlanCache::default();
        cold.attach_db(db::PlanDb::in_memory());
        let mut cold_plans = Vec::new();
        for (i, csr) in csrs.iter().enumerate() {
            let hwio = gemm_hwio(csr.rows, csr.cols);
            cold_plans.push(cold.plan_node(&format!("c{i}"), FormatPolicy::Auto,
                ValuePolicy::Auto, None, csr, 196, hwio, false));
        }
        assert_eq!(cold.tune_stats().searched, csrs.len());
        // move the populated database into a fresh cache (a new build)
        let text = cold.db().unwrap().to_json().to_string_pretty();
        let mut warm = PlanCache::default();
        warm.attach_db(db::PlanDb::load_str(&text).unwrap());
        for (i, csr) in csrs.iter().enumerate() {
            let hwio = gemm_hwio(csr.rows, csr.cols);
            let lp = warm.plan_node(&format!("c{i}"), FormatPolicy::Auto, ValuePolicy::Auto,
                None, csr, 196, hwio, false);
            assert_eq!(lp, cold_plans[i], "warm plan must be identical");
        }
        let st = warm.tune_stats();
        assert_eq!(st.db_hits, csrs.len());
        assert_eq!((st.searched, st.measurements), (0, 0));
    }
}
