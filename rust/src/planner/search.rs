//! Search-based per-layer planning over the compositional space
//! `SparseFormat` x BSR block shape x reorder x `value_bits` x parallel
//! cutover.
//!
//! The heuristic planner ([`super::choose`]) walks a fixed menu with
//! switch margins; this module *searches* the same space top-down,
//! priced through a device generation's [`CostTable`] (so recalibrated
//! constants — `cadnn calibrate --apply-db` — change the answers without
//! a recompile), and is what [`super::PlanCache::plan_node`] runs when a
//! plan database or `--tune` is attached:
//!
//! - **branch and bound**: cheap families (CSR, Dense) are priced
//!   exactly in O(1); expensive families (BSR needs block counting and
//!   possibly column clustering, Pattern needs kernel counting) are
//!   visited in a fixed order behind O(1) *lower bounds* — a family
//!   whose bound already exceeds the incumbent is pruned un-evaluated.
//!   Pruning is strict-inequality only, so exact ties never make the
//!   outcome depend on visit order;
//! - **seeds**: plans remembered by the database (any generation — see
//!   `super::db`) have their families priced first, tightening the
//!   incumbent before the bounds are consulted. Seeds never change the
//!   winner (the winner is the exact minimum either way); they only
//!   shrink the work;
//! - **beam measurement** (`--tune`): the top [`BEAM`] candidates by
//!   modeled cost are timed with the real serial kernels on the layer's
//!   own weights (the same micro-benchmark loop as
//!   [`super::choose_measured`]), the beam re-ranks on measured time
//!   (CSR keeps ties, modeled order breaks measured ties), and the
//!   winner's parallel cutover is refined from its measured per-row
//!   cost. Modeled `cost_per_row` is kept on every candidate so costs
//!   stay comparable across layers and batch sizes.
//!
//! The returned candidates are ranked best-first — exactly what
//! `super::db::PlanDb::insert` persists and what a warm
//! `PlanDb::best_plan` answers later, which is why a warm replan is
//! bit-identical to the cold search that seeded it.

use super::db::{CostTable, StoredCandidate};
use super::{
    pattern_eligible, resolve_value_bits, FormatPolicy, LayerArtifacts, LayerPlan, SparseFormat,
    ValuePolicy, BSR_CANDIDATES, PARALLEL_DISPATCH_US,
};
use crate::compress::bsr;
use crate::compress::bsr::BsrMatrix;
use crate::compress::csr::CsrMatrix;
use crate::compress::pattern;
use crate::compress::pattern::PatternMatrix;
use crate::compress::reorder;
use crate::kernels::{Epilogue, PARALLEL_M_CUTOVER};
use crate::passes::layout::TileConfig;

/// Candidates timed with real kernels in measured mode.
pub const BEAM: usize = 3;

/// One search result: ranked candidates (best first) and how many kernel
/// measurements ran (0 in modeled mode — the counter CI asserts on).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchOutcome {
    pub candidates: Vec<StoredCandidate>,
    pub measurements: usize,
}

impl SearchOutcome {
    /// The winning plan (rank 0). Only empty for degenerate inputs the
    /// caller already filtered.
    pub fn best(&self) -> Option<&StoredCandidate> {
        self.candidates.first()
    }
}

/// The search's family axis: which exact-evaluation step produces a
/// format's candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    Csr,
    Dense,
    Bsr(usize, usize),
    Pattern,
}

impl Family {
    fn of(format: SparseFormat) -> Family {
        match format {
            SparseFormat::Csr => Family::Csr,
            SparseFormat::Dense => Family::Dense,
            SparseFormat::Bsr { br, bc } => Family::Bsr(br, bc),
            SparseFormat::Pattern => Family::Pattern,
        }
    }
}

/// Deterministic candidate ordering: modeled cost, then format label,
/// reorder, cutover — so equal-cost candidates rank identically however
/// the search visited them.
fn rank_key(c: &StoredCandidate) -> (f64, String, bool, usize) {
    (c.cost, c.plan.format.label(), c.plan.reorder, c.plan.parallel_cutover)
}

fn sort_candidates(cands: &mut [StoredCandidate]) {
    cands.sort_by(|a, b| {
        let (ka, kb) = (rank_key(a), rank_key(b));
        ka.0.total_cmp(&kb.0).then_with(|| ka.1.cmp(&kb.1).then(ka.2.cmp(&kb.2)).then(
            ka.3.cmp(&kb.3)))
    });
}

/// Search one layer's plan. `table` prices every candidate (the current
/// device generation); `seeds` are remembered plans priced first;
/// `measure` times the top [`BEAM`] with real kernels; `seed` makes the
/// measurement inputs deterministic per spec ([`super::db::spec_seed`]).
/// Candidates come back ranked best-first with modeled `cost` (and
/// `measured_us` where timed).
#[allow(clippy::too_many_arguments)]
pub fn search_layer(
    policy: FormatPolicy,
    value_policy: ValuePolicy,
    declared: Option<u8>,
    csr: &CsrMatrix,
    m: usize,
    hwio: [usize; 4],
    table: &CostTable,
    seeds: &[LayerPlan],
    measure: bool,
    seed: u64,
    arts: &mut LayerArtifacts,
) -> SearchOutcome {
    let (k, n, nnz) = (csr.rows, csr.cols, csr.nnz());
    if nnz == 0 || k == 0 || n == 0 {
        return SearchOutcome {
            candidates: vec![StoredCandidate {
                plan: LayerPlan::csr(),
                cost: 0.0,
                measured_us: None,
            }],
            measurements: 0,
        };
    }

    let eligible = pattern_eligible(csr, hwio);
    // the policy's family menu, in the fixed (deterministic) visit order
    let menu: Vec<Family> = match policy {
        FormatPolicy::Csr => vec![Family::Csr],
        FormatPolicy::Bsr => BSR_CANDIDATES.iter().map(|&(br, bc, _)| Family::Bsr(br,
            bc)).collect(),
        FormatPolicy::Pattern => {
            if eligible {
                vec![Family::Pattern]
            } else {
                vec![Family::Csr]
            }
        }
        FormatPolicy::Auto => {
            let mut v = vec![Family::Csr, Family::Dense];
            v.extend(BSR_CANDIDATES.iter().map(|&(br, bc, _)| Family::Bsr(br, bc)));
            if eligible {
                v.push(Family::Pattern);
            }
            v
        }
    };

    // per-format value widths (fixed per format, never searched freely:
    // free choice would always land on f32 — the LUT factors are > 1 —
    // and lose the quantized payload the profile asked for)
    let vb_sparse = resolve_value_bits(value_policy, declared, SparseFormat::Csr);
    let lut = table.lut_factor(vb_sparse);

    let cutover_for = |cost_per_row: f64| -> usize {
        match table.us_per_unit {
            Some(u) if cost_per_row > 0.0 && u > 0.0 => {
                // rows before the pool dispatch amortizes to <50% overhead
                // at the modeled per-row wall-clock cost
                let rows = (2.0 * PARALLEL_DISPATCH_US / (cost_per_row * u)).ceil();
                if rows.is_finite() {
                    (rows as usize).max(PARALLEL_M_CUTOVER)
                } else {
                    PARALLEL_M_CUTOVER
                }
            }
            _ => PARALLEL_M_CUTOVER,
        }
    };
    let cand = |format: SparseFormat, reorder: bool, cost: f64| -> StoredCandidate {
        StoredCandidate {
            plan: LayerPlan {
                format,
                value_bits: resolve_value_bits(value_policy, declared, format),
                reorder,
                parallel_cutover: cutover_for(cost),
                cost_per_row: cost,
                rows_per_image: 0,
            },
            cost,
            measured_us: None,
        }
    };

    // exact family evaluation (the "expand" step); expensive families
    // do their block/kernel counting here, memoized in `arts`
    let mut evaluated: Vec<Family> = Vec::new();
    let mut candidates: Vec<StoredCandidate> = Vec::new();
    let mut best = f64::INFINITY;
    let mut expand = |fam: Family,
                      evaluated: &mut Vec<Family>,
                      candidates: &mut Vec<StoredCandidate>,
                      best: &mut f64,
                      arts: &mut LayerArtifacts| {
        if evaluated.contains(&fam) {
            return;
        }
        evaluated.push(fam);
        let mut push = |c: StoredCandidate, best: &mut f64| {
            if c.cost < *best {
                *best = c.cost;
            }
            candidates.push(c);
        };
        match fam {
            Family::Csr => push(cand(SparseFormat::Csr, false, nnz as f64 * table.csr_nnz *
                lut), best),
            Family::Dense => {
                // dense rematerializes the zeros and has no LUT path
                push(cand(SparseFormat::Dense, false, (k * n) as f64 * table.dense_mac), best);
            }
            Family::Bsr(br, bc) => {
                let (blocks, reorder_on) = arts.blocks_for(csr, br, bc);
                let rate = table.bsr(br, bc);
                push(
                    cand(
                        SparseFormat::Bsr { br, bc },
                        reorder_on,
                        (blocks * br * bc) as f64 * rate * lut,
                    ),
                    best,
                );
                if reorder_on {
                    // the hysteresis picked the permuted layout; keep the
                    // plain layout as a ranked alternative so the database
                    // remembers both sides of the reorder axis
                    let plain = bsr::count_blocks(csr, br, bc);
                    push(
                        cand(SparseFormat::Bsr { br, bc }, false, (plain * br * bc) as f64 *
                            rate * lut),
                        best,
                    );
                }
            }
            Family::Pattern => {
                let kernels = pattern::count_kernels(csr, hwio[2]);
                push(
                    cand(
                        SparseFormat::Pattern,
                        false,
                        nnz as f64 * table.pattern_val * lut
                            + kernels as f64 * table.pattern_kernel,
                    ),
                    best,
                );
            }
        }
    };

    // seeds first: exact-price the families the database remembers, so
    // the incumbent is tight before any bound is consulted
    for s in seeds {
        let fam = Family::of(s.format);
        if menu.contains(&fam) {
            expand(fam, &mut evaluated, &mut candidates, &mut best, arts);
        }
    }
    // then the rest of the menu, cheapest-to-bound first, pruning on a
    // strict bound violation (ties are never pruned: determinism)
    for &fam in &menu {
        let bound = match fam {
            // O(1) families: no useful bound, always expand
            Family::Csr | Family::Dense => f64::NEG_INFINITY,
            // every stored block covers >= 1 nonzero
            Family::Bsr(br, bc) => nnz as f64 * table.bsr(br, bc) * lut,
            // every surviving kernel covers <= kh*kw nonzeros
            Family::Pattern => {
                let kk = (hwio[0] * hwio[1]).max(1);
                nnz as f64 * table.pattern_val * lut
                    + (nnz.div_ceil(kk)) as f64 * table.pattern_kernel
            }
        };
        if bound > best {
            continue;
        }
        expand(fam, &mut evaluated, &mut candidates, &mut best, arts);
    }

    sort_candidates(&mut candidates);

    let mut measurements = 0;
    if measure && !candidates.is_empty() {
        let beam = BEAM.min(candidates.len());
        let mm = m.clamp(1, super::MEASURE_M_CAP);
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut a = vec![0.0f32; mm * k];
        rng.fill_normal(&mut a, 0.5);
        let mut c = vec![0.0f32; mm * n];
        let mut timed: Vec<(usize, f64, f64)> = Vec::new(); // (rank, eff_us, raw_us)
        for (rank, sc) in candidates.iter().take(beam).enumerate() {
            let raw = match sc.plan.format {
                SparseFormat::Csr => super::measure_us(|| {
                    crate::kernels::sparse::csr_gemm(&a, csr, &mut c, mm, &Epilogue::None);
                }),
                SparseFormat::Dense => {
                    let dense = arts.dense(csr);
                    super::measure_us(|| {
                        crate::kernels::gemm::gemm_blocked(
                            &a,
                            &dense,
                            &mut c,
                            mm,
                            k,
                            n,
                            &TileConfig::DEFAULT,
                            &Epilogue::None,
                        );
                    })
                }
                SparseFormat::Bsr { br, bc } => {
                    let dense = arts.dense(csr);
                    let mat = if sc.plan.reorder {
                        let perm = arts.permutation(csr, br).clone();
                        let permuted = reorder::permute_cols(&dense, k, n, &perm);
                        BsrMatrix::from_dense(&permuted, k, n, br, bc)
                    } else {
                        BsrMatrix::from_dense(&dense, k, n, br, bc)
                    };
                    super::measure_us(|| {
                        crate::kernels::bsr::bsr_gemm(&a, &mat, &mut c, mm, &Epilogue::None);
                    })
                }
                SparseFormat::Pattern => {
                    let dense = arts.dense(csr);
                    let mat = PatternMatrix::from_dense(&dense, hwio[0], hwio[1], hwio[2], n);
                    super::measure_us(|| {
                        crate::kernels::pattern::pattern_gemm(&a, &mat, &mut c, mm,
                            &Epilogue::None);
                    })
                }
            };
            measurements += 1;
            // CSR keeps ties, mirroring choose_measured
            let eff = if sc.plan.format == SparseFormat::Csr { raw * 0.98 } else { raw };
            timed.push((rank, eff, raw));
        }
        // re-rank the beam on measured time; modeled rank breaks ties
        timed.sort_by(|x, y| x.1.total_cmp(&y.1).then(x.0.cmp(&y.0)));
        let mut beam_ranked: Vec<StoredCandidate> = Vec::with_capacity(beam);
        for &(rank, eff, raw) in &timed {
            let mut sc = candidates[rank].clone();
            if raw.is_finite() {
                sc.measured_us = Some(raw);
            }
            if beam_ranked.is_empty() {
                // the measured winner: refine its cutover from the
                // measured per-row cost
                let per_row_us = eff.max(1e-3) / mm as f64;
                let rows = (2.0 * PARALLEL_DISPATCH_US / per_row_us).ceil() as usize;
                sc.plan.parallel_cutover = rows.max(PARALLEL_M_CUTOVER);
            }
            beam_ranked.push(sc);
        }
        beam_ranked.extend(candidates.into_iter().skip(beam));
        candidates = beam_ranked;
    }

    SearchOutcome { candidates, measurements }
}

#[cfg(test)]
mod tests {
    use super::super::{plan_layer_valued, COST_CSR_NNZ, COST_LUT_Q4};
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(k: usize, n: usize, density: f64, seed: u64) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; k * n];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        CsrMatrix::from_dense(&dense, k, n)
    }

    fn modeled(
        policy: FormatPolicy,
        csr: &CsrMatrix,
        hwio: [usize; 4],
        seeds: &[LayerPlan],
    ) -> SearchOutcome {
        search_layer(
            policy,
            ValuePolicy::Auto,
            None,
            csr,
            196,
            hwio,
            &CostTable::builtin(),
            seeds,
            false,
            7,
            &mut LayerArtifacts::default(),
        )
    }

    /// The acceptance property: against the builtin table, the searched
    /// winner's modeled cost never exceeds the heuristic plan's modeled
    /// cost (the search takes the exact minimum of a superset of the
    /// heuristic's menu; the heuristic's switch margins can only keep it
    /// on a costlier baseline).
    #[test]
    fn searched_cost_never_exceeds_heuristic() {
        for seed in 0..40u64 {
            let density = 0.02 + 0.02 * (seed % 30) as f64;
            let (k, n) = (16 + 8 * (seed % 5) as usize, 16 + 4 * (seed % 7) as usize);
            let csr = random_csr(k, n, density, seed);
            let hwio = [1, 1, k, n];
            let heur = plan_layer_valued(
                FormatPolicy::Auto,
                ValuePolicy::Auto,
                None,
                &csr,
                196,
                hwio,
                &mut LayerArtifacts::default(),
            );
            let out = modeled(FormatPolicy::Auto, &csr, hwio, &[]);
            let best = out.best().unwrap();
            assert!(
                best.cost <= heur.cost_per_row + 1e-9,
                "seed {seed}: searched {} > heuristic {} ({:?} vs {:?})",
                best.cost,
                heur.cost_per_row,
                best.plan.format,
                heur.format
            );
            assert_eq!(out.measurements, 0, "modeled mode must not measure");
        }
    }

    #[test]
    fn builtin_table_prices_like_the_unit_model() {
        let csr = random_csr(64, 32, 0.08, 3);
        let out = modeled(FormatPolicy::Csr, &csr, [1, 1, 64, 32], &[]);
        let best = out.best().unwrap();
        assert_eq!(best.plan.format, SparseFormat::Csr);
        assert_eq!(best.cost, csr.nnz() as f64 * COST_CSR_NNZ);
        assert_eq!(best.cost, best.plan.cost_per_row);
    }

    #[test]
    fn seeds_do_not_change_the_winner() {
        for seed in 0..20u64 {
            let csr = random_csr(96, 48, 0.05 + 0.03 * (seed % 10) as f64, 100 + seed);
            let hwio = [1, 1, 96, 48];
            let cold = modeled(FormatPolicy::Auto, &csr, hwio, &[]);
            // seed with every cold candidate (the warm-db scenario)
            let seeds: Vec<LayerPlan> =
                cold.candidates.iter().map(|c| c.plan.clone()).collect();
            let warm = modeled(FormatPolicy::Auto, &csr, hwio, &seeds);
            assert_eq!(
                warm.best().unwrap().plan,
                cold.best().unwrap().plan,
                "seed {seed}: seeds changed the winner"
            );
        }
    }

    #[test]
    fn quantized_payloads_scale_costs_and_keep_format() {
        let csr = random_csr(128, 64, 0.08, 1);
        let hwio = [1, 1, 128, 64];
        let f32_out = modeled(FormatPolicy::Auto, &csr, hwio, &[]);
        let q4 = search_layer(
            FormatPolicy::Auto,
            ValuePolicy::Q4,
            None,
            &csr,
            196,
            hwio,
            &CostTable::builtin(),
            &[],
            false,
            7,
            &mut LayerArtifacts::default(),
        );
        let (f, q) = (f32_out.best().unwrap(), q4.best().unwrap());
        assert_eq!(f.plan.format, SparseFormat::Csr);
        assert_eq!(q.plan.format, SparseFormat::Csr);
        assert_eq!(q.plan.value_bits, crate::compress::qsparse::ValueBits::Q4);
        assert!((q.cost - f.cost * COST_LUT_Q4).abs() < 1e-9);
    }

    #[test]
    fn calibrated_scale_raises_cutovers_for_cheap_layers() {
        let csr = random_csr(32, 16, 0.1, 5);
        let mut table = CostTable::builtin();
        // cost_per_row ~ nnz ~ 51 units; at 0.01 µs/unit one row is
        // ~0.5µs, so amortizing 60µs of dispatch needs >100 rows
        table.us_per_unit = Some(0.01);
        let out = search_layer(
            FormatPolicy::Csr,
            ValuePolicy::Auto,
            None,
            &csr,
            196,
            [1, 1, 32, 16],
            &table,
            &[],
            false,
            7,
            &mut LayerArtifacts::default(),
        );
        let best = out.best().unwrap();
        let expect = (2.0 * PARALLEL_DISPATCH_US / (best.cost * 0.01)).ceil() as usize;
        assert_eq!(best.plan.parallel_cutover, expect.max(PARALLEL_M_CUTOVER));
        assert!(best.plan.parallel_cutover > PARALLEL_M_CUTOVER);
    }

    #[test]
    fn degenerate_and_pinned_menus() {
        // empty matrix: the csr baseline, nothing measured
        let empty = CsrMatrix::from_dense(&[0.0f32; 64], 8, 8);
        let out = modeled(FormatPolicy::Auto, &empty, [1, 1, 8, 8], &[]);
        assert_eq!(out.best().unwrap().plan, LayerPlan::csr());
        // pattern policy off-spatial falls back to csr, like the heuristic
        let csr = random_csr(64, 32, 0.1, 9);
        let out = modeled(FormatPolicy::Pattern, &csr, [1, 1, 64, 32], &[]);
        assert_eq!(out.best().unwrap().plan.format, SparseFormat::Csr);
        // bsr pin searches only block shapes
        let out = modeled(FormatPolicy::Bsr, &csr, [1, 1, 64, 32], &[]);
        assert!(out
            .candidates
            .iter()
            .all(|c| matches!(c.plan.format, SparseFormat::Bsr { .. })));
    }

    #[test]
    fn measured_mode_times_the_beam_and_refines_cutover() {
        let csr = random_csr(48, 24, 0.25, 7);
        let out = search_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            64,
            [1, 1, 48, 24],
            &CostTable::builtin(),
            &[],
            true,
            11,
            &mut LayerArtifacts::default(),
        );
        assert!(out.measurements >= 1 && out.measurements <= BEAM);
        let best = out.best().unwrap();
        assert!(best.measured_us.is_some(), "the winner must carry its timing");
        assert!(best.plan.parallel_cutover >= PARALLEL_M_CUTOVER);
        assert!(best.cost > 0.0, "modeled cost survives measurement");
    }
}
