//! The persistent plan database: memoized planner search results, keyed
//! by layer *spec*, versioned by *device generation*.
//!
//! [`super::search`] explores the compositional per-layer space (format x
//! block shape x reorder x value width x cutover). That exploration is
//! worth memoizing across builds and models: two layers with the same
//! shape and sparsity *structure* under the same policies cost the same,
//! whatever model they came from. [`PlanDb`] stores the top-k
//! [`super::LayerPlan`] candidates per [`SpecKey`] in one JSON file
//! (`~/.cache/cadnn/plandb.json` or `--plan-db PATH`), so tuning cost is
//! paid once per (shape, structure, device) family.
//!
//! **Device generations.** Each entry is keyed to the cost-model
//! generation it was searched under: a [`CostTable`] (the `COST_*`
//! constants, possibly re-fitted by `cadnn calibrate --cost-report
//! --apply-db`, plus the calibrated µs/unit scale) fingerprinted into a
//! generation id. A new generation *soft-invalidates* older entries:
//! they stop answering exact lookups but remain available as search
//! seeds ([`PlanDb::seed_plans`]), so recalibration never throws the
//! searched space away.
//!
//! **Durability.** Loading never panics and never errors out of a build:
//! a missing file is a fresh database, and a corrupt / truncated /
//! wrong-version / oversized file degrades to a cold (empty) database
//! with a [`crate::warn!`] — the same anti-DoS discipline as
//! `cadnn::front` (hard caps on file size, entry count, and candidate
//! count). Saving goes through a temp file + atomic rename, so a reader
//! racing a writer sees either the old or the new file, never a torn
//! one.

use super::{FormatPolicy, LayerPlan, ValuePolicy};
use crate::compress::csr::CsrMatrix;
use crate::compress::qsparse::ValueBits;
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// On-disk format version; a mismatch degrades to a cold database (old
/// files are not migrated — plans are cheap to re-search).
pub const FORMAT_VERSION: usize = 1;
/// Candidates retained per spec (ranked best-first).
pub const TOP_K: usize = 4;

// Anti-DoS caps, mirroring `front::parser`: a hostile or corrupt file is
// rejected (degrading to a cold database), never chased.
const MAX_FILE_BYTES: usize = 1 << 26;
const MAX_ENTRIES: usize = 1 << 16;
const MAX_CANDIDATES: usize = 16;
const MAX_GENERATIONS: usize = 64;
const MAX_SPEC_DIM: usize = 1 << 48;
const MAX_HITS: f64 = (1u64 << 50) as f64;

fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex64(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

fn fnv(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100000001b3)
}

const FNV_BASIS: u64 = 0xcbf29ce484222325;

// ---------------------------------------------------------------------------
// SpecKey
// ---------------------------------------------------------------------------

/// What makes two layers "the same layer" to the planner: geometry, the
/// sparsity *structure* (support fingerprint — values don't change
/// format costs), the planning policies, the declared codebook width,
/// and the device generation the costs were searched under.
///
/// `device_fp` sorts last, so one `BTreeMap` range scan finds every
/// generation's entry for a spec ([`PlanDb::seed_plans`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SpecKey {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Conv weight shape `[kh, kw, cin, cout]` (`[1, 1, k, n]` for GEMM)
    /// — the spatial-vs-GEMM and pattern-eligibility signal.
    pub hwio: [usize; 4],
    /// FNV-1a over the CSR support (`col_idx` + `row_ptr`), *not* the
    /// values: two prunings with the same support cost the same in every
    /// format, whatever the surviving magnitudes are.
    pub support_fp: u64,
    pub policy: FormatPolicy,
    pub value_policy: ValuePolicy,
    /// Codebook width the compress report declared for this layer
    /// (`SparsityProfile::quant_bits`), resolved by `ValuePolicy::Auto`.
    pub declared: Option<u8>,
    /// The [`CostTable`] generation id ([`CostTable::fingerprint`]).
    pub device_fp: u64,
}

/// FNV-1a over a CSR matrix's support only (shape + `col_idx` +
/// `row_ptr`) — the structure part of a [`SpecKey`].
pub fn support_fingerprint(csr: &CsrMatrix) -> u64 {
    let mut h = FNV_BASIS;
    h = fnv(h, csr.rows as u64);
    h = fnv(h, csr.cols as u64);
    for &c in &csr.col_idx {
        h = fnv(h, c as u64);
    }
    for &p in &csr.row_ptr {
        h = fnv(h, p as u64);
    }
    h
}

/// The deterministic tie/measurement seed for a layer spec — what
/// [`super::choose_measured`] seeds its input generator from, so
/// identical specs resolve identically across builds and processes
/// (device-independent: the generation does not change the layer).
pub fn spec_seed(
    policy: FormatPolicy,
    value_policy: ValuePolicy,
    declared: Option<u8>,
    csr: &CsrMatrix,
    hwio: [usize; 4],
) -> u64 {
    SpecKey::from_layer(policy, value_policy, declared, csr, hwio, 0).seed()
}

impl SpecKey {
    /// Build the key for one pruned layer under the given policies and
    /// device generation.
    pub fn from_layer(
        policy: FormatPolicy,
        value_policy: ValuePolicy,
        declared: Option<u8>,
        csr: &CsrMatrix,
        hwio: [usize; 4],
        device_fp: u64,
    ) -> SpecKey {
        SpecKey {
            rows: csr.rows,
            cols: csr.cols,
            nnz: csr.nnz(),
            hwio,
            support_fp: support_fingerprint(csr),
            policy,
            value_policy,
            declared,
            device_fp,
        }
    }

    /// FNV-1a over every field — the spec's deterministic hash, used to
    /// seed measurement inputs and break exact cost ties.
    pub fn seed(&self) -> u64 {
        let mut h = FNV_BASIS;
        for v in [self.rows, self.cols, self.nnz] {
            h = fnv(h, v as u64);
        }
        for v in self.hwio {
            h = fnv(h, v as u64);
        }
        h = fnv(h, self.support_fp);
        for &b in self.policy.label().as_bytes() {
            h = fnv(h, b as u64);
        }
        for &b in self.value_policy.label().as_bytes() {
            h = fnv(h, b as u64);
        }
        h = fnv(h, self.declared.map(|b| b as u64 + 1).unwrap_or(0));
        h = fnv(h, self.device_fp);
        h
    }

    /// The same spec under a different device generation.
    pub fn with_device(&self, device_fp: u64) -> SpecKey {
        SpecKey { device_fp, ..*self }
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("nnz", Json::Num(self.nnz as f64)),
            (
                "hwio",
                Json::Arr(self.hwio.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
            ("support", Json::Str(hex64(self.support_fp))),
            ("policy", Json::Str(self.policy.label().to_string())),
            ("values", Json::Str(self.value_policy.label().to_string())),
        ];
        if let Some(b) = self.declared {
            kv.push(("declared", Json::Num(b as f64)));
        }
        kv.push(("device", Json::Str(hex64(self.device_fp))));
        obj(kv)
    }

    pub fn from_json(j: &Json) -> Option<SpecKey> {
        let dim = |key: &str| -> Option<usize> {
            let v = j.get(key)?.as_usize()?;
            (v <= MAX_SPEC_DIM).then_some(v)
        };
        let Json::Arr(hw) = j.get("hwio")? else {
            return None;
        };
        if hw.len() != 4 {
            return None;
        }
        let mut hwio = [0usize; 4];
        for (slot, v) in hwio.iter_mut().zip(hw) {
            let d = v.as_usize()?;
            if d > MAX_SPEC_DIM {
                return None;
            }
            *slot = d;
        }
        let declared = match j.get("declared") {
            None => None,
            Some(v) => {
                let b = v.as_usize()?;
                if b == 0 || b > 32 {
                    return None;
                }
                Some(b as u8)
            }
        };
        Some(SpecKey {
            rows: dim("rows")?,
            cols: dim("cols")?,
            nnz: dim("nnz")?,
            hwio,
            support_fp: parse_hex64(j.get("support")?.as_str()?)?,
            policy: FormatPolicy::parse(j.get("policy")?.as_str()?)?,
            value_policy: ValuePolicy::parse(j.get("values")?.as_str()?)?,
            declared,
            device_fp: parse_hex64(j.get("device")?.as_str()?)?,
        })
    }
}

// ---------------------------------------------------------------------------
// CostTable + generations
// ---------------------------------------------------------------------------

/// One device generation's cost model: the `planner::COST_*` constants
/// (possibly re-fitted from [`crate::obs::report::CostReport`]
/// residuals) plus the calibrated units→µs scale, when one converged.
/// Fingerprinted into the generation id every [`SpecKey`] carries.
#[derive(Debug, Clone, PartialEq)]
pub struct CostTable {
    pub dense_mac: f64,
    pub csr_nnz: f64,
    pub bsr_4x1: f64,
    pub bsr_4x4: f64,
    pub pattern_val: f64,
    pub pattern_kernel: f64,
    pub lut_q8: f64,
    pub lut_q4: f64,
    /// Calibrated wall-clock scale (µs per cost unit) from a profiled
    /// run; lets the search derive real parallel cutovers without
    /// measuring. `None` before any calibration reached the table.
    pub us_per_unit: Option<f64>,
}

impl CostTable {
    /// The compiled-in constants — the generation every fresh database
    /// starts from.
    pub fn builtin() -> CostTable {
        CostTable {
            dense_mac: super::COST_DENSE_MAC,
            csr_nnz: super::COST_CSR_NNZ,
            bsr_4x1: super::COST_BSR_4X1,
            bsr_4x4: super::COST_BSR_4X4,
            pattern_val: super::COST_PATTERN_VAL,
            pattern_kernel: super::COST_PATTERN_KERNEL,
            lut_q8: super::COST_LUT_Q8,
            lut_q4: super::COST_LUT_Q4,
            us_per_unit: None,
        }
    }

    fn fields(&self) -> [f64; 8] {
        [
            self.dense_mac,
            self.csr_nnz,
            self.bsr_4x1,
            self.bsr_4x4,
            self.pattern_val,
            self.pattern_kernel,
            self.lut_q8,
            self.lut_q4,
        ]
    }

    /// FNV-1a over the constants' bit patterns and the calibration — the
    /// device generation id.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_BASIS;
        for v in self.fields() {
            h = fnv(h, v.to_bits());
        }
        match self.us_per_unit {
            None => h = fnv(h, 0),
            Some(u) => {
                h = fnv(h, 1);
                h = fnv(h, u.to_bits());
            }
        }
        h
    }

    /// Set one constant by its `planner::COST_*` name (the names
    /// [`crate::obs::report::CostReport::suggestions`] emits). Rejects
    /// unknown names and non-finite / non-positive values.
    pub fn apply(&mut self, name: &str, value: f64) -> bool {
        if !value.is_finite() || value <= 0.0 {
            return false;
        }
        let slot = match name {
            "COST_DENSE_MAC" => &mut self.dense_mac,
            "COST_CSR_NNZ" => &mut self.csr_nnz,
            "COST_BSR_4X1" => &mut self.bsr_4x1,
            "COST_BSR_4X4" => &mut self.bsr_4x4,
            "COST_PATTERN_VAL" => &mut self.pattern_val,
            "COST_PATTERN_KERNEL" => &mut self.pattern_kernel,
            "COST_LUT_Q8" => &mut self.lut_q8,
            "COST_LUT_Q4" => &mut self.lut_q4,
            _ => return false,
        };
        *slot = value;
        true
    }

    /// The LUT cost multiplier for a value width (1.0 for f32) — the
    /// table-driven counterpart of [`super::lut_cost_factor`].
    pub fn lut_factor(&self, v: ValueBits) -> f64 {
        match v {
            ValueBits::F32 => 1.0,
            ValueBits::Q8 => self.lut_q8,
            ValueBits::Q4 => self.lut_q4,
        }
    }

    /// Per-stored-value cost of a BSR block shape (unknown shapes fall
    /// back to the 4x1 rate, like [`super::BSR_CANDIDATES`] pricing).
    pub fn bsr(&self, br: usize, bc: usize) -> f64 {
        match (br, bc) {
            (4, 1) => self.bsr_4x1,
            (4, 4) => self.bsr_4x4,
            _ => self.bsr_4x1,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("dense_mac", Json::Num(self.dense_mac)),
            ("csr_nnz", Json::Num(self.csr_nnz)),
            ("bsr_4x1", Json::Num(self.bsr_4x1)),
            ("bsr_4x4", Json::Num(self.bsr_4x4)),
            ("pattern_val", Json::Num(self.pattern_val)),
            ("pattern_kernel", Json::Num(self.pattern_kernel)),
            ("lut_q8", Json::Num(self.lut_q8)),
            ("lut_q4", Json::Num(self.lut_q4)),
        ];
        if let Some(u) = self.us_per_unit {
            kv.push(("us_per_unit", Json::Num(u)));
        }
        obj(kv)
    }

    pub fn from_json(j: &Json) -> Option<CostTable> {
        let pos = |key: &str| -> Option<f64> {
            let v = j.get(key)?.as_f64()?;
            (v.is_finite() && v > 0.0).then_some(v)
        };
        let us_per_unit = match j.get("us_per_unit") {
            None => None,
            Some(v) => {
                let u = v.as_f64()?;
                if !u.is_finite() || u <= 0.0 {
                    return None;
                }
                Some(u)
            }
        };
        Some(CostTable {
            dense_mac: pos("dense_mac")?,
            csr_nnz: pos("csr_nnz")?,
            bsr_4x1: pos("bsr_4x1")?,
            bsr_4x4: pos("bsr_4x4")?,
            pattern_val: pos("pattern_val")?,
            pattern_kernel: pos("pattern_kernel")?,
            lut_q8: pos("lut_q8")?,
            lut_q4: pos("lut_q4")?,
            us_per_unit,
        })
    }
}

/// One device profile generation: an id (the table fingerprint), a
/// monotonically growing sequence number, the table itself, and a
/// human-readable provenance note.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub id: u64,
    pub seq: usize,
    pub note: String,
    pub table: CostTable,
}

impl Generation {
    fn builtin() -> Generation {
        let table = CostTable::builtin();
        Generation { id: table.fingerprint(), seq: 0, note: "builtin".to_string(), table }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("id", Json::Str(hex64(self.id))),
            ("seq", Json::Num(self.seq as f64)),
            ("note", Json::Str(self.note.clone())),
            ("costs", self.table.to_json()),
        ])
    }

    fn from_json(j: &Json) -> Option<Generation> {
        let table = CostTable::from_json(j.get("costs")?)?;
        let id = parse_hex64(j.get("id")?.as_str()?)?;
        if id != table.fingerprint() {
            return None; // tampered / hand-edited: id must match the table
        }
        Some(Generation {
            id,
            seq: j.get("seq")?.as_usize()?,
            note: j.get("note")?.as_str()?.to_string(),
            table,
        })
    }
}

// ---------------------------------------------------------------------------
// Entries
// ---------------------------------------------------------------------------

/// Where an entry's candidates came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// Cost-model search, no kernel timing.
    Modeled,
    /// Search refined by real-kernel measurement (`--tune`).
    Measured,
    /// Merged in by `cadnn db import`.
    Imported,
}

impl Provenance {
    pub fn label(&self) -> &'static str {
        match self {
            Provenance::Modeled => "modeled",
            Provenance::Measured => "measured",
            Provenance::Imported => "imported",
        }
    }

    pub fn parse(s: &str) -> Option<Provenance> {
        match s {
            "modeled" => Some(Provenance::Modeled),
            "measured" => Some(Provenance::Measured),
            "imported" => Some(Provenance::Imported),
            _ => None,
        }
    }
}

/// One ranked plan candidate: the plan, its modeled cost per GEMM row
/// (comparable across generations of the same table), and the measured
/// serial µs when `--tune` timed it.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredCandidate {
    pub plan: LayerPlan,
    pub cost: f64,
    pub measured_us: Option<f64>,
}

impl StoredCandidate {
    /// Dedup identity: two candidates proposing the same execution
    /// configuration are the same candidate.
    fn identity(&self) -> (String, usize, bool, usize) {
        (
            self.plan.format.label(),
            self.plan.value_bits.bits(),
            self.plan.reorder,
            self.plan.parallel_cutover,
        )
    }

    fn to_json(&self) -> Json {
        let mut kv = vec![("plan", self.plan.to_json()), ("cost", Json::Num(self.cost))];
        if let Some(us) = self.measured_us {
            kv.push(("measured_us", Json::Num(us)));
        }
        obj(kv)
    }

    fn from_json(j: &Json) -> Option<StoredCandidate> {
        let cost = j.get("cost")?.as_f64()?;
        if !cost.is_finite() || cost < 0.0 {
            return None;
        }
        let measured_us = match j.get("measured_us") {
            None => None,
            Some(v) => {
                let us = v.as_f64()?;
                if !us.is_finite() || us < 0.0 {
                    return None;
                }
                Some(us)
            }
        };
        Some(StoredCandidate { plan: LayerPlan::from_json(j.get("plan")?)?, cost, measured_us })
    }
}

/// One spec's memoized search result: candidates ranked best-first
/// (index 0 is what [`PlanDb::best_plan`] answers), plus hit/provenance
/// metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct DbEntry {
    pub candidates: Vec<StoredCandidate>,
    pub hits: u64,
    pub provenance: Provenance,
}

impl DbEntry {
    fn to_json(&self, spec: &SpecKey) -> Json {
        obj(vec![
            ("spec", spec.to_json()),
            ("hits", Json::Num(self.hits as f64)),
            ("provenance", Json::Str(self.provenance.label().to_string())),
            (
                "candidates",
                Json::Arr(self.candidates.iter().map(StoredCandidate::to_json).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Option<(SpecKey, DbEntry)> {
        let spec = SpecKey::from_json(j.get("spec")?)?;
        let hits_f = j.get("hits")?.as_f64()?;
        if !(0.0..=MAX_HITS).contains(&hits_f) {
            return None;
        }
        let Json::Arr(cands) = j.get("candidates")? else {
            return None;
        };
        if cands.is_empty() || cands.len() > MAX_CANDIDATES {
            return None;
        }
        let candidates =
            cands.iter().map(StoredCandidate::from_json).collect::<Option<Vec<_>>>()?;
        Some((
            spec,
            DbEntry {
                candidates,
                hits: hits_f as u64,
                provenance: Provenance::parse(j.get("provenance")?.as_str()?)?,
            },
        ))
    }
}

// ---------------------------------------------------------------------------
// The database
// ---------------------------------------------------------------------------

/// Session counters the tuning pipeline reports (`cadnn plan --tune`
/// prints them; CI asserts on them): how many planning requests were
/// answered from where, and how many kernel measurements actually ran.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TuneStats {
    /// Planning requests (pruned layer x batch variant).
    pub requests: usize,
    /// Answered by the in-process memo (same spec, later variant).
    pub memo_hits: usize,
    /// Answered by the database (exact spec + current generation).
    pub db_hits: usize,
    /// Cold: a search (or legacy heuristic/measured planning) ran.
    pub searched: usize,
    /// Individual kernel timings performed across all searches.
    pub measurements: usize,
}

impl TuneStats {
    /// One-line counters summary (the `plan-db:` line CI greps).
    pub fn render(&self) -> String {
        format!(
            "requests={} memo_hits={} db_hits={} searched={} measurements={}",
            self.requests, self.memo_hits, self.db_hits, self.searched, self.measurements
        )
    }
}

/// Aggregate statistics for `cadnn db stats`.
#[derive(Debug, Clone, PartialEq)]
pub struct DbStats {
    pub entries: usize,
    pub candidates: usize,
    pub hits: u64,
    pub generations: usize,
    pub current: u64,
    /// Entries under the current generation (exact-answer eligible).
    pub current_entries: usize,
    /// Entries from older generations (seed-only).
    pub stale_entries: usize,
}

impl DbStats {
    pub fn render(&self) -> String {
        format!(
            "entries={} (current={} stale={}) candidates={} hits={} generations={} \
             current_generation={}",
            self.entries,
            self.current_entries,
            self.stale_entries,
            self.candidates,
            self.hits,
            self.generations,
            hex64(self.current)
        )
    }
}

/// The on-disk plan database. See the module doc for the design; the
/// lifecycle is `open` → (`best_plan` | `seed_plans` | `insert`)* →
/// `save`.
#[derive(Debug)]
pub struct PlanDb {
    path: Option<PathBuf>,
    generations: Vec<Generation>,
    current: u64,
    entries: BTreeMap<SpecKey, DbEntry>,
    degraded: Option<String>,
    dirty: bool,
}

impl PlanDb {
    fn fresh(path: Option<PathBuf>) -> PlanDb {
        let g = Generation::builtin();
        PlanDb {
            path,
            current: g.id,
            generations: vec![g],
            entries: BTreeMap::new(),
            degraded: None,
            dirty: false,
        }
    }

    /// A database with no backing file (`save` is a no-op) — build-time
    /// ephemeral use and tests.
    pub fn in_memory() -> PlanDb {
        PlanDb::fresh(None)
    }

    /// Open (or create) the database at `path`. Never fails: a missing
    /// file is a fresh database; an unreadable or invalid one degrades
    /// to a fresh database with a warning ([`PlanDb::degraded`] carries
    /// the reason).
    pub fn open(path: impl Into<PathBuf>) -> PlanDb {
        let path = path.into();
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return PlanDb::fresh(Some(path));
            }
            Err(e) => {
                return PlanDb::degraded_fresh(Some(path), format!("unreadable: {e}"));
            }
        };
        if bytes.len() > MAX_FILE_BYTES {
            return PlanDb::degraded_fresh(
                Some(path),
                format!("file exceeds {} bytes cap", MAX_FILE_BYTES),
            );
        }
        let text = match std::str::from_utf8(&bytes) {
            Ok(t) => t,
            Err(e) => {
                return PlanDb::degraded_fresh(Some(path), format!("not utf-8: {e}"));
            }
        };
        match PlanDb::load_str(text) {
            Ok(mut db) => {
                db.path = Some(path);
                db
            }
            Err(e) => PlanDb::degraded_fresh(Some(path), e),
        }
    }

    fn degraded_fresh(path: Option<PathBuf>, reason: String) -> PlanDb {
        crate::warn!(
            "plandb",
            "plan db {} is invalid ({reason}); starting cold",
            path.as_deref().map(|p| p.display().to_string()).unwrap_or_default()
        );
        let mut db = PlanDb::fresh(path);
        db.degraded = Some(reason);
        db
    }

    /// Parse a serialized database. All validation lives here so the
    /// fuzz corpora can drive it directly; every rejection is a typed
    /// reason string, never a panic.
    pub fn load_str(text: &str) -> Result<PlanDb, String> {
        if text.len() > MAX_FILE_BYTES {
            return Err(format!("file exceeds {MAX_FILE_BYTES} bytes cap"));
        }
        let j = Json::parse(text).map_err(|e| format!("json: {e}"))?;
        match j.get("cadnn_plandb").and_then(|v| v.as_usize()) {
            Some(v) if v == FORMAT_VERSION => {}
            Some(v) => return Err(format!("format version {v}, expected {FORMAT_VERSION}")),
            None => return Err("missing cadnn_plandb version key".to_string()),
        }
        let current =
            parse_hex64(j.get("current").and_then(|v| v.as_str()).unwrap_or_default())
                .ok_or("missing/invalid current generation id")?;
        let Some(Json::Arr(gens)) = j.get("generations") else {
            return Err("missing generations array".to_string());
        };
        if gens.is_empty() || gens.len() > MAX_GENERATIONS {
            return Err(format!("generation count {} outside 1..={}", gens.len(),
                MAX_GENERATIONS));
        }
        let mut generations = Vec::with_capacity(gens.len());
        for g in gens {
            generations.push(Generation::from_json(g).ok_or("malformed generation")?);
        }
        if !generations.iter().any(|g| g.id == current) {
            return Err("current generation id not in generation list".to_string());
        }
        let Some(Json::Arr(ents)) = j.get("entries") else {
            return Err("missing entries array".to_string());
        };
        if ents.len() > MAX_ENTRIES {
            return Err(format!("entry count {} exceeds {} cap", ents.len(), MAX_ENTRIES));
        }
        let mut entries = BTreeMap::new();
        for e in ents {
            let (spec, entry) = DbEntry::from_json(e).ok_or("malformed entry")?;
            entries.insert(spec, entry);
        }
        Ok(PlanDb { path: None, generations, current, entries, degraded: None, dirty: false })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("cadnn_plandb", Json::Num(FORMAT_VERSION as f64)),
            ("current", Json::Str(hex64(self.current))),
            (
                "generations",
                Json::Arr(self.generations.iter().map(Generation::to_json).collect()),
            ),
            (
                "entries",
                Json::Arr(self.entries.iter().map(|(s, e)| e.to_json(s)).collect()),
            ),
        ])
    }

    /// Persist to the backing file (temp file + atomic rename; parent
    /// directories are created). No-op without a path or when nothing
    /// changed since the last save.
    pub fn save(&mut self) -> Result<(), String> {
        let Some(path) = self.path.clone() else {
            return Ok(());
        };
        if !self.dirty {
            return Ok(());
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir:?}: {e}"))?;
            }
        }
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        let text = self.to_json().to_string_pretty();
        std::fs::write(&tmp, text).map_err(|e| format!("write {tmp:?}: {e}"))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("rename to {path:?}: {e}"))?;
        self.dirty = false;
        Ok(())
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Why the backing file was discarded at open, if it was.
    pub fn degraded(&self) -> Option<&str> {
        self.degraded.as_deref()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The current device generation id — the `device_fp` new
    /// [`SpecKey`]s should carry.
    pub fn device_fp(&self) -> u64 {
        self.current
    }

    /// The current generation's cost table.
    pub fn current_table(&self) -> &CostTable {
        self.generations
            .iter()
            .find(|g| g.id == self.current)
            .map(|g| &g.table)
            .expect("current generation always exists")
    }

    pub fn generations(&self) -> &[Generation] {
        &self.generations
    }

    /// Exact lookup: the best stored plan for this spec under its own
    /// generation. Records the hit. Entries from other generations never
    /// answer here — they only seed ([`PlanDb::seed_plans`]).
    pub fn best_plan(&mut self, spec: &SpecKey) -> Option<LayerPlan> {
        let e = self.entries.get_mut(spec)?;
        e.hits = e.hits.saturating_add(1);
        self.dirty = true;
        Some(e.candidates.first()?.plan.clone())
    }

    /// Stored plans for this spec under *any* generation, best-first per
    /// generation — cold searches price these first so a recalibrated
    /// database converges in one exact pricing per seed instead of a
    /// full re-exploration.
    pub fn seed_plans(&self, spec: &SpecKey) -> Vec<LayerPlan> {
        let lo = spec.with_device(0);
        let hi = spec.with_device(u64::MAX);
        let mut out = Vec::new();
        for (_, e) in self.entries.range(lo..=hi) {
            for c in &e.candidates {
                if !out.contains(&c.plan) {
                    out.push(c.plan.clone());
                }
            }
        }
        out
    }

    /// Record a search result: candidates ranked best-first (the search
    /// owns the ranking — a measured winner stays first even when a
    /// modeled cost disagrees). Replaces any previous candidates for the
    /// spec, keeps accumulated hits, truncates to [`TOP_K`].
    pub fn insert(&mut self, spec: SpecKey, candidates: Vec<StoredCandidate>, prov: Provenance) {
        if candidates.is_empty() || self.entries.len() >= MAX_ENTRIES {
            return;
        }
        let mut ranked: Vec<StoredCandidate> = Vec::new();
        for c in candidates {
            if !c.cost.is_finite() || c.cost < 0.0 {
                continue;
            }
            if ranked.iter().all(|r| r.identity() != c.identity()) {
                ranked.push(c);
            }
        }
        ranked.truncate(TOP_K);
        if ranked.is_empty() {
            return;
        }
        let hits = self.entries.get(&spec).map(|e| e.hits).unwrap_or(0);
        self.entries.insert(spec, DbEntry { candidates: ranked, hits, provenance: prov });
        self.dirty = true;
    }

    /// Install a new device generation (id = the table's fingerprint)
    /// and make it current. Existing entries keep their old generation
    /// key — soft-invalidated into seeds. Returns the new id; a table
    /// identical to an existing generation just re-selects it.
    pub fn new_generation(&mut self, table: CostTable, note: &str) -> Result<u64, String> {
        let id = table.fingerprint();
        if let Some(g) = self.generations.iter().find(|g| g.id == id) {
            let id = g.id;
            if self.current != id {
                self.current = id;
                self.dirty = true;
            }
            return Ok(id);
        }
        if self.generations.len() >= MAX_GENERATIONS {
            return Err(format!("generation cap {MAX_GENERATIONS} reached; prune first"));
        }
        let seq = self.generations.iter().map(|g| g.seq).max().unwrap_or(0) + 1;
        self.generations.push(Generation { id, seq, note: note.to_string(), table });
        self.current = id;
        self.dirty = true;
        Ok(id)
    }

    /// Fold a cost report into a new generation: re-fitted constants
    /// from `suggestions` (unknown names are skipped), the fitted
    /// µs/unit scale when positive. Returns the new generation id.
    pub fn apply_calibration(
        &mut self,
        suggestions: &[(&str, f64, f64)],
        us_per_unit: Option<f64>,
        note: &str,
    ) -> Result<u64, String> {
        let mut table = self.current_table().clone();
        for (name, _, suggested) in suggestions {
            table.apply(name, *suggested);
        }
        if let Some(u) = us_per_unit {
            if u.is_finite() && u > 0.0 {
                table.us_per_unit = Some(u);
            }
        }
        self.new_generation(table, note)
    }

    /// Drop every entry not under the current generation (and every
    /// non-current generation). Returns (kept, dropped) entry counts.
    pub fn prune(&mut self) -> (usize, usize) {
        let before = self.entries.len();
        self.entries.retain(|s, _| s.device_fp == self.current);
        let dropped = before - self.entries.len();
        let had_gens = self.generations.len();
        self.generations.retain(|g| g.id == self.current);
        if dropped > 0 || had_gens != self.generations.len() {
            self.dirty = true;
        }
        (self.entries.len(), dropped)
    }

    /// Merge another database's entries (marked [`Provenance::Imported`]
    /// unless already present) and unknown generations into this one.
    /// Hits are summed for entries both sides know; candidate lists keep
    /// the local ranking and append novel imported candidates up to
    /// [`TOP_K`]. Returns (new entries, merged entries).
    pub fn merge(&mut self, other: &PlanDb) -> (usize, usize) {
        for g in &other.generations {
            if !self.generations.iter().any(|m| m.id == g.id)
                && self.generations.len() < MAX_GENERATIONS
            {
                self.generations.push(g.clone());
                self.dirty = true;
            }
        }
        let (mut added, mut merged) = (0, 0);
        for (spec, theirs) in &other.entries {
            match self.entries.get_mut(spec) {
                None => {
                    if self.entries.len() >= MAX_ENTRIES {
                        continue;
                    }
                    let mut e = theirs.clone();
                    e.provenance = Provenance::Imported;
                    e.candidates.truncate(TOP_K);
                    self.entries.insert(*spec, e);
                    added += 1;
                    self.dirty = true;
                }
                Some(mine) => {
                    mine.hits = mine.hits.saturating_add(theirs.hits);
                    for c in &theirs.candidates {
                        if mine.candidates.len() >= TOP_K {
                            break;
                        }
                        if mine.candidates.iter().all(|m| m.identity() != c.identity()) {
                            mine.candidates.push(c.clone());
                        }
                    }
                    merged += 1;
                    self.dirty = true;
                }
            }
        }
        (added, merged)
    }

    pub fn stats(&self) -> DbStats {
        let current_entries =
            self.entries.keys().filter(|s| s.device_fp == self.current).count();
        DbStats {
            entries: self.entries.len(),
            candidates: self.entries.values().map(|e| e.candidates.len()).sum(),
            hits: self.entries.values().map(|e| e.hits).sum(),
            generations: self.generations.len(),
            current: self.current,
            current_entries,
            stale_entries: self.entries.len() - current_entries,
        }
    }
}

/// The default database location: `$CADNN_PLAN_DB`, else
/// `$XDG_CACHE_HOME/cadnn/plandb.json`, else
/// `$HOME/.cache/cadnn/plandb.json` (relative `./plandb.json` as the
/// last resort).
pub fn default_path() -> PathBuf {
    if let Ok(p) = std::env::var("CADNN_PLAN_DB") {
        if !p.is_empty() {
            return PathBuf::from(p);
        }
    }
    let base = std::env::var("XDG_CACHE_HOME").ok().filter(|p| !p.is_empty()).map(
        PathBuf::from,
    );
    let base = base.or_else(|| {
        std::env::var("HOME")
            .ok()
            .filter(|p| !p.is_empty())
            .map(|h| PathBuf::from(h).join(".cache"))
    });
    match base {
        Some(b) => b.join("cadnn").join("plandb.json"),
        None => PathBuf::from("plandb.json"),
    }
}

#[cfg(test)]
mod tests {
    use super::super::SparseFormat;
    use super::*;

    fn tiny_csr(seed: u64) -> CsrMatrix {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut dense = vec![0.0f32; 32 * 16];
        for v in dense.iter_mut() {
            if rng.f64() < 0.2 {
                *v = rng.normal() as f32;
            }
        }
        CsrMatrix::from_dense(&dense, 32, 16)
    }

    fn spec(seed: u64, device_fp: u64) -> SpecKey {
        SpecKey::from_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &tiny_csr(seed),
            [1, 1, 32, 16],
            device_fp,
        )
    }

    fn cand(format: SparseFormat, cost: f64) -> StoredCandidate {
        StoredCandidate {
            plan: LayerPlan { format, cost_per_row: cost, ..LayerPlan::csr() },
            cost,
            measured_us: None,
        }
    }

    #[test]
    fn spec_key_json_roundtrip() {
        for s in [
            spec(1, CostTable::builtin().fingerprint()),
            SpecKey {
                declared: Some(4),
                policy: FormatPolicy::Bsr,
                value_policy: ValuePolicy::Q4,
                ..spec(2, 7)
            },
        ] {
            let j = s.to_json();
            assert_eq!(SpecKey::from_json(&j), Some(s));
        }
        // values don't enter the key: same support, different magnitudes
        let a = tiny_csr(3);
        let mut b = a.clone();
        for v in b.values.iter_mut() {
            *v *= 2.0;
        }
        assert_eq!(support_fingerprint(&a), support_fingerprint(&b));
        // ...but a different support does
        assert_ne!(support_fingerprint(&a), support_fingerprint(&tiny_csr(4)));
    }

    #[test]
    fn spec_seed_is_device_free_and_deterministic() {
        let csr = tiny_csr(5);
        let s1 = spec_seed(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, [1, 1, 32, 16]);
        let s2 = spec_seed(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, [1, 1, 32, 16]);
        assert_eq!(s1, s2);
        let s3 = spec_seed(FormatPolicy::Auto, ValuePolicy::Q8, None, &csr, [1, 1, 32, 16]);
        assert_ne!(s1, s3, "policy axis must reach the seed");
    }

    #[test]
    fn cost_table_builtin_fingerprint_and_apply() {
        let t = CostTable::builtin();
        assert_eq!(t.fingerprint(), CostTable::builtin().fingerprint());
        let mut t2 = t.clone();
        assert!(t2.apply("COST_CSR_NNZ", 1.3));
        assert_ne!(t2.fingerprint(), t.fingerprint());
        assert!(!t2.apply("COST_NOPE", 1.0));
        assert!(!t2.apply("COST_CSR_NNZ", f64::NAN));
        assert!(!t2.apply("COST_CSR_NNZ", 0.0));
        // calibration alone is a new generation too
        let mut t3 = t.clone();
        t3.us_per_unit = Some(0.01);
        assert_ne!(t3.fingerprint(), t.fingerprint());
        let j = t3.to_json();
        assert_eq!(CostTable::from_json(&j), Some(t3));
    }

    #[test]
    fn insert_ranks_dedups_and_caps_at_top_k() {
        let mut db = PlanDb::in_memory();
        let s = spec(1, db.device_fp());
        let cands = vec![
            cand(SparseFormat::Csr, 5.0),
            cand(SparseFormat::Csr, 9.0), // duplicate identity: dropped
            cand(SparseFormat::Dense, 6.0),
            cand(SparseFormat::Bsr { br: 4, bc: 1 }, 7.0),
            cand(SparseFormat::Bsr { br: 4, bc: 4 }, 8.0),
            cand(SparseFormat::Pattern, 9.0), // beyond TOP_K: evicted
        ];
        db.insert(s, cands, Provenance::Modeled);
        let e = db.entries.get(&s).unwrap();
        assert_eq!(e.candidates.len(), TOP_K);
        let labels: Vec<String> =
            e.candidates.iter().map(|c| c.plan.format.label()).collect();
        assert_eq!(labels, ["csr", "dense", "bsr4x1", "bsr4x4"], "ranked order preserved");
        // best_plan answers rank 0 and records the hit
        assert_eq!(db.best_plan(&s).unwrap().format, SparseFormat::Csr);
        assert_eq!(db.entries.get(&s).unwrap().hits, 1);
        // a re-insert keeps accumulated hits
        db.insert(s, vec![cand(SparseFormat::Dense, 4.0)], Provenance::Measured);
        let e = db.entries.get(&s).unwrap();
        assert_eq!(e.hits, 1);
        assert_eq!(e.provenance, Provenance::Measured);
        assert_eq!(e.candidates.len(), 1);
    }

    #[test]
    fn generations_soft_invalidate_into_seeds() {
        let mut db = PlanDb::in_memory();
        let s = spec(1, db.device_fp());
        db.insert(s, vec![cand(SparseFormat::Dense, 3.0)], Provenance::Modeled);
        assert!(db.best_plan(&s).is_some());

        let mut table = db.current_table().clone();
        table.apply("COST_CSR_NNZ", 1.4);
        let new_fp = db.new_generation(table, "recalibrated").unwrap();
        assert_ne!(new_fp, s.device_fp);
        assert_eq!(db.device_fp(), new_fp);

        // the old entry no longer answers under the new generation...
        let s_new = s.with_device(new_fp);
        assert!(db.best_plan(&s_new).is_none(), "stale entries must not answer");
        // ...but still seeds the search for the same layer
        let seeds = db.seed_plans(&s_new);
        assert_eq!(seeds.len(), 1);
        assert_eq!(seeds[0].format, SparseFormat::Dense);

        // an identical table re-selects the existing generation
        let again = db.new_generation(db.current_table().clone(), "same").unwrap();
        assert_eq!(again, new_fp);
        assert_eq!(db.generations().len(), 2);

        // prune drops the stale entry and the old generation
        let (kept, dropped) = db.prune();
        assert_eq!((kept, dropped), (0, 1));
        assert_eq!(db.generations().len(), 1);
    }

    #[test]
    fn save_open_roundtrip_and_stats() {
        let path = std::env::temp_dir()
            .join(format!("cadnn_plandb_rt_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut db = PlanDb::open(&path);
        assert!(db.degraded().is_none(), "missing file is fresh, not degraded");
        let s = spec(1, db.device_fp());
        db.insert(
            s,
            vec![
                StoredCandidate {
                    plan: LayerPlan {
                        format: SparseFormat::Bsr { br: 4, bc: 4 },
                        value_bits: ValueBits::Q4,
                        reorder: true,
                        parallel_cutover: 96,
                        cost_per_row: 172.8,
                        rows_per_image: 0,
                    },
                    cost: 172.8,
                    measured_us: Some(13.25),
                },
                cand(SparseFormat::Csr, 200.0),
            ],
            Provenance::Measured,
        );
        db.best_plan(&s);
        db.save().unwrap();

        let mut back = PlanDb::open(&path);
        assert!(back.degraded().is_none());
        assert_eq!(back.len(), 1);
        let plan = back.best_plan(&s).unwrap();
        assert_eq!(plan.format, SparseFormat::Bsr { br: 4, bc: 4 });
        assert_eq!(plan.value_bits, ValueBits::Q4);
        assert!(plan.reorder);
        assert_eq!(plan.parallel_cutover, 96);
        assert_eq!(plan.cost_per_row, 172.8, "f64 costs round-trip bit-exactly");
        let st = back.stats();
        assert_eq!(st.entries, 1);
        assert_eq!(st.candidates, 2);
        assert_eq!(st.hits, 2, "hits persist and accumulate");
        assert_eq!(st.current_entries, 1);
        assert!(st.render().contains("entries=1"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_bump_and_junk_degrade_cold() {
        let mut db = PlanDb::in_memory();
        let s = spec(1, db.device_fp());
        db.insert(s, vec![cand(SparseFormat::Csr, 5.0)], Provenance::Modeled);
        let mut text = db.to_json().to_string_pretty();
        assert!(PlanDb::load_str(&text).is_ok());
        // a future format version must not half-load
        text = text.replace("\"cadnn_plandb\": 1", "\"cadnn_plandb\": 2");
        let err = PlanDb::load_str(&text).unwrap_err();
        assert!(err.contains("version"), "{err}");
        for junk in ["", "{", "[1,2,3]", "{\"cadnn_plandb\": 1}", "\u{0}\u{0}"] {
            assert!(PlanDb::load_str(junk).is_err(), "{junk:?} must not load");
        }
        // open() on a junk file warns + degrades instead of failing
        let path = std::env::temp_dir()
            .join(format!("cadnn_plandb_junk_{}.json", std::process::id()));
        std::fs::write(&path, "{\"cadnn_plandb\": \"nope\"").unwrap();
        let db = PlanDb::open(&path);
        assert!(db.degraded().is_some());
        assert!(db.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_sums_hits_and_marks_imports() {
        let mut a = PlanDb::in_memory();
        let mut b = PlanDb::in_memory();
        let fp = a.device_fp();
        let s1 = spec(1, fp);
        let s2 = spec(2, fp);
        a.insert(s1, vec![cand(SparseFormat::Csr, 5.0)], Provenance::Modeled);
        a.best_plan(&s1);
        b.insert(
            s1,
            vec![cand(SparseFormat::Csr, 5.0), cand(SparseFormat::Dense, 6.0)],
            Provenance::Measured,
        );
        b.best_plan(&s1);
        b.best_plan(&s1);
        b.insert(s2, vec![cand(SparseFormat::Pattern, 2.0)], Provenance::Modeled);
        let (added, merged) = a.merge(&b);
        assert_eq!((added, merged), (1, 1));
        let e1 = a.entries.get(&s1).unwrap();
        assert_eq!(e1.hits, 3, "hits summed");
        assert_eq!(e1.provenance, Provenance::Modeled, "local provenance kept");
        assert_eq!(e1.candidates.len(), 2, "novel imported candidate appended");
        assert_eq!(a.entries.get(&s2).unwrap().provenance, Provenance::Imported);
    }

    #[test]
    fn default_path_honors_env_override() {
        // CADNN_PLAN_DB is read at call time; don't mutate the process
        // env in tests (other tests run in parallel) — just check the
        // fallback shape.
        let p = default_path();
        assert!(p.to_string_lossy().ends_with("plandb.json") || p.is_absolute());
    }
}
