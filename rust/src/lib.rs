//! CADNN — compression-aware DNN inference for mobile, reproduced as a
//! three-layer Rust + JAX + Pallas stack. See DESIGN.md and docs/API.md.
//!
//! # The front door: `EngineBuilder → Engine → Session`
//!
//! All inference — native kernels or AOT PJRT artifacts — goes through
//! [`api`]:
//!
//! ```ignore
//! use cadnn::api::Engine;
//! use cadnn::exec::Personality;
//!
//! let engine = Engine::native("resnet50")
//!     .personality(Personality::CadnnSparse)
//!     .sparsity_profile(profile)
//!     .tuned(true)
//!     .batch_sizes(&[1, 4, 8])
//!     .build()?;
//!
//! let mut session = engine.session();
//! let logits = session.run(&image)?; // repeated runs reuse buffers
//! ```
//!
//! Beneath the engine sits the pluggable [`api::Backend`] trait with two
//! implementations: [`api::NativeBackend`] (in-process kernels, always
//! available) and [`api::ArtifactBackend`] (PJRT over AOT HLO artifacts).
//! The multi-model [`serve::Server`] drives any `Box<dyn Backend>`: each
//! registered model gets its own queue and a deadline-aware dynamic
//! batcher whose batch-size choice runs on the planner's cost model
//! ([`planner::ExecPlan::cost_at`]):
//!
//! ```ignore
//! use cadnn::serve::{ServeRequest, Server};
//! let server = Server::builder().engine("resnet50", &engine).build()?;
//! let resp = server.infer(
//!     ServeRequest::new("resnet50", image).deadline_ms(30).topk(5),
//! )?;                                      // Ok(logits) | Deadline | Backend
//! let stats = server.stats();              // per-model snapshots
//! ```
//!
//! (The old single-model [`coordinator::Coordinator`] remains as a thin
//! deprecated shim over `serve` — see `docs/SERVING.md`.)
//!
//! Errors are typed ([`error::CadnnError`]) below the API boundary and
//! `anyhow` at the binary/example boundary.
//!
//! # The compression pipeline
//!
//! The full train → ADMM prune (element / block / PatDNN pattern) →
//! profile export → `cadnn plan` → planned execution walkthrough lives
//! in `docs/PIPELINE.md`; `docs/FORMATS.md` documents the sparse weight
//! formats ([`compress`]) and the per-layer planner ([`planner`]) that
//! turn those profiles into kernel choices.
//!
//! # Layer map
//!
//! | module        | role                                                     |
//! |---------------|----------------------------------------------------------|
//! | [`api`]       | Engine/Session/Backend — the public inference surface    |
//! | [`error`]     | `CadnnError`, the crate-wide typed error enum            |
//! | [`front`]     | `.cadnn` textual model IR: parser + canonical printer    |
//! | [`ir`]        | dataflow graph IR of the exact paper architectures       |
//! | [`models`]    | graph builders (ResNet-50, MobileNets, Inception, §3 nets)|
//! | [`passes`]    | fusion / 1x1→GEMM / layout / load-elimination passes     |
//! | [`exec`]      | native executor: personalities, instances, scratch reuse |
//! | [`kernels`]   | dense/CSR/BSR/pattern GEMM, conv engines, epilogues      |
//! | [`compress`]  | CSR/BSR/pattern weights, reordering, profiles, sizes     |
//! | [`planner`]   | per-layer format choice + batch cost model (`cost_at`)   |
//! | [`tuner`]     | optimization-parameter selection (paper §4)              |
//! | [`runtime`]   | PJRT artifact loader (vendored stub offline)             |
//! | [`serve`]     | multi-model Server: deadline-aware planner-driven batching|
//! | [`coordinator`]| deprecated single-model shim over [`serve`]             |
//! | [`costmodel`] | device projection behind Figure 2                        |
//! | [`obs`]       | spans, counters, histograms, cost residuals (tracing)    |
//! | [`bench`]     | Figure 2 / Table 2 regeneration harnesses                |
//! | [`util`]      | offline substrate: json, rng, stats, thread pool, prop   |

// Index-juggling numeric kernels read clearer with explicit indices, and
// tests build dense matrices with `&vec![..]` literals; the CI clippy
// gate runs with -D warnings, so both idioms are allowed once here
// rather than per-site.
#![allow(clippy::needless_range_loop, clippy::useless_vec)]

pub mod api;
pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod costmodel;
pub mod error;
pub mod exec;
pub mod front;
pub mod ir;
pub mod kernels;
pub mod models;
pub mod obs;
pub mod passes;
pub mod planner;
pub mod runtime;
pub mod serve;
pub mod tuner;
pub mod util;

pub use api::{Backend, Engine, EngineBuilder, Session};
pub use error::CadnnError;
pub use serve::{ServeRequest, ServeResponse, Server};
