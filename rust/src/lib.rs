//! CADNN — compression-aware DNN inference for mobile, reproduced as a
//! three-layer Rust + JAX + Pallas stack. See DESIGN.md.

pub mod bench;
pub mod ir;
pub mod kernels;
pub mod compress;
pub mod models;
pub mod passes;
pub mod costmodel;
pub mod coordinator;
pub mod exec;
pub mod tuner;
pub mod runtime;
pub mod util;
