//! Layer operators, their parameter counts and work (FLOP) accounting.
//!
//! Pre-pass graphs contain the "textbook" ops (Conv2d, BatchNorm, Act as
//! separate nodes); the paper's fusion/transformation passes rewrite them
//! into the fused forms (`FusedConvBnAct`, `Gemm`, ...) that carry a
//! schedule and map 1:1 onto executable kernels.

use super::shape::{conv_out, Shape};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    Relu,
    Relu6,
    None,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input placeholder.
    Input { shape: Shape },
    /// Standard convolution, NHWC x HWIO. `padding` is symmetric;
    /// `groups` > 1 models grouped conv (AlexNet conv2/4/5).
    Conv2d { kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padh: usize, padw: usize, bias: bool, groups: usize },
    /// Depthwise convolution (channel multiplier 1).
    DepthwiseConv2d { kh: usize, kw: usize, c: usize, stride: usize, padding: usize },
    /// Inference BatchNorm (folds to per-channel affine).
    BatchNorm { c: usize },
    Activation { kind: ActKind },
    Pool { kind: PoolKind, k: usize, stride: usize, padding: usize },
    GlobalAvgPool,
    FullyConnected { cin: usize, cout: usize, bias: bool },
    /// Elementwise residual add (two inputs).
    Add,
    /// Channel concat (>= 2 inputs).
    Concat,
    Softmax,
    Flatten,

    // ----- post-pass fused / transformed ops -----
    /// Conv + folded BN + activation in one kernel (paper §4 fusion).
    FusedConvBnAct { kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padh: usize, padw: usize, act: ActKind, groups: usize },
    /// Depthwise conv + folded BN + activation.
    FusedDwBnAct { kh: usize, kw: usize, c: usize, stride: usize, padding: usize, act: ActKind },
    /// 1x1 conv rewritten as (N*H*W, Cin) x (Cin, Cout) GEMM (paper §4
    /// transformation); `act`/`bn` carried as a fused epilogue.
    Gemm { m: usize, k: usize, n: usize, act: ActKind, fused_epilogue: bool, out_shape: Shape },
}

impl Op {
    /// Dense conv, no bias, groups=1 (the BN-style model family).
    pub fn conv(kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padding: usize) -> Op {
        Op::Conv2d { kh, kw, cin, cout, stride, padh: padding, padw: padding, bias: false, groups: 1 }
    }

    /// Asymmetric-kernel conv (Inception 1x7/7x1), no bias, groups=1.
    pub fn conv_asym(kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padh: usize, padw: usize) -> Op {
        Op::Conv2d { kh, kw, cin, cout, stride, padh, padw, bias: false, groups: 1 }
    }

    /// Conv with bias (classic pre-BN nets: LeNet/AlexNet/VGG).
    pub fn conv_b(kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padding: usize) -> Op {
        Op::Conv2d { kh, kw, cin, cout, stride, padh: padding, padw: padding, bias: true, groups: 1 }
    }

    /// Grouped conv with bias (AlexNet conv2/4/5).
    pub fn conv_bg(kh: usize, kw: usize, cin: usize, cout: usize, stride: usize, padding: usize, groups: usize) -> Op {
        Op::Conv2d { kh, kw, cin, cout, stride, padh: padding, padw: padding, bias: true, groups }
    }

    pub fn fc(cin: usize, cout: usize) -> Op {
        Op::FullyConnected { cin, cout, bias: true }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv2d { .. } => "conv2d",
            Op::DepthwiseConv2d { .. } => "dwconv2d",
            Op::BatchNorm { .. } => "batchnorm",
            Op::Activation { .. } => "activation",
            Op::Pool { .. } => "pool",
            Op::GlobalAvgPool => "global_avg_pool",
            Op::FullyConnected { .. } => "fc",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Softmax => "softmax",
            Op::Flatten => "flatten",
            Op::FusedConvBnAct { .. } => "fused_conv_bn_act",
            Op::FusedDwBnAct { .. } => "fused_dw_bn_act",
            Op::Gemm { .. } => "gemm",
        }
    }

    /// Trainable weight count (what pruning operates on; biases/BN params
    /// counted separately in `aux_params`).
    pub fn weight_count(&self) -> usize {
        match self {
            Op::Conv2d { kh, kw, cin, cout, groups, .. } => kh * kw * (cin / groups) * cout,
            Op::DepthwiseConv2d { kh, kw, c, .. } => kh * kw * c,
            Op::FullyConnected { cin, cout, .. } => cin * cout,
            Op::FusedConvBnAct { kh, kw, cin, cout, groups, .. } => kh * kw * (cin / groups) * cout,
            Op::FusedDwBnAct { kh, kw, c, .. } => kh * kw * c,
            Op::Gemm { k, n, .. } => k * n,
            _ => 0,
        }
    }

    /// Bias / BN parameter count.
    pub fn aux_params(&self) -> usize {
        match self {
            Op::Conv2d { cout, bias, .. } => if *bias { *cout } else { 0 },
            Op::FullyConnected { cout, bias, .. } => if *bias { *cout } else { 0 },
            Op::BatchNorm { c } => 4 * c,
            // fused ops carry the folded scale+shift
            Op::FusedConvBnAct { cout, .. } => 2 * cout,
            Op::FusedDwBnAct { c, .. } => 2 * c,
            Op::Gemm { n, fused_epilogue, .. } => if *fused_epilogue { 2 * n } else { *n },
            _ => 0,
        }
    }

    /// Whether this op is a pruning target (has a weight matrix).
    pub fn prunable(&self) -> bool {
        self.weight_count() > 0 && !matches!(self, Op::DepthwiseConv2d { .. } | Op::FusedDwBnAct { .. })
    }

    /// Infer output shape from input shapes.
    pub fn infer_shape(&self, inputs: &[&Shape]) -> Shape {
        match self {
            Op::Input { shape } => shape.clone(),
            Op::Conv2d { kh, kw, cout, stride, padh, padw, cin, .. }
            | Op::FusedConvBnAct { kh, kw, cout, stride, padh, padw, cin, .. } => {
                let s = inputs[0];
                debug_assert_eq!(s.c(), *cin, "conv cin mismatch");
                Shape::nhwc(
                    s.n(),
                    conv_out(s.h(), *kh, *stride, *padh),
                    conv_out(s.w(), *kw, *stride, *padw),
                    *cout,
                )
            }
            Op::DepthwiseConv2d { kh, kw, c, stride, padding }
            | Op::FusedDwBnAct { kh, kw, c, stride, padding, .. } => {
                let s = inputs[0];
                debug_assert_eq!(s.c(), *c, "dwconv channel mismatch");
                Shape::nhwc(
                    s.n(),
                    conv_out(s.h(), *kh, *stride, *padding),
                    conv_out(s.w(), *kw, *stride, *padding),
                    *c,
                )
            }
            Op::BatchNorm { .. } | Op::Activation { .. } | Op::Add | Op::Softmax => {
                inputs[0].clone()
            }
            Op::Pool { k, stride, padding, .. } => {
                let s = inputs[0];
                Shape::nhwc(
                    s.n(),
                    conv_out(s.h(), *k, *stride, *padding),
                    conv_out(s.w(), *k, *stride, *padding),
                    s.c(),
                )
            }
            Op::GlobalAvgPool => {
                let s = inputs[0];
                Shape::vec2(s.n(), s.c())
            }
            Op::FullyConnected { cout, .. } => Shape::vec2(inputs[0].n(), *cout),
            Op::Concat => {
                let s0 = inputs[0];
                let c: usize = inputs.iter().map(|s| s.c()).sum();
                Shape::nhwc(s0.n(), s0.h(), s0.w(), c)
            }
            Op::Flatten => {
                let s = inputs[0];
                Shape::vec2(s.n(), s.numel() / s.n())
            }
            Op::Gemm { out_shape, .. } => out_shape.clone(),
        }
    }

    /// Multiply-accumulate FLOPs (2 * MACs) for the op given its input
    /// and output shapes. Elementwise ops count 1 FLOP/element.
    pub fn flops(&self, inputs: &[&Shape], output: &Shape) -> u64 {
        let out_n = output.numel() as u64;
        match self {
            Op::Conv2d { kh, kw, cin, groups, .. }
            | Op::FusedConvBnAct { kh, kw, cin, groups, .. } => {
                let macs = out_n * (*kh * *kw * (*cin / *groups)) as u64;
                2 * macs + if matches!(self, Op::FusedConvBnAct { .. }) { 2 * out_n } else { 0 }
            }
            Op::DepthwiseConv2d { kh, kw, .. } | Op::FusedDwBnAct { kh, kw, .. } => {
                2 * out_n * (*kh * *kw) as u64
            }
            Op::BatchNorm { .. } => 2 * out_n,
            Op::Activation { .. } => out_n,
            Op::Pool { k, .. } => out_n * (*k * *k) as u64,
            Op::GlobalAvgPool => inputs[0].numel() as u64,
            Op::FullyConnected { cin, .. } => 2 * out_n * *cin as u64,
            Op::Add => out_n,
            Op::Concat | Op::Flatten | Op::Input { .. } => 0,
            Op::Softmax => 5 * out_n,
            Op::Gemm { m, k, n, .. } => 2 * (*m * *k * *n) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_weight_count() {
        let op = Op::conv(3, 3, 64, 128, 1, 1);
        assert_eq!(op.weight_count(), 3 * 3 * 64 * 128);
        assert!(op.prunable());
    }

    #[test]
    fn depthwise_not_prunable() {
        let op = Op::DepthwiseConv2d { kh: 3, kw: 3, c: 32, stride: 1, padding: 1 };
        assert_eq!(op.weight_count(), 288);
        assert!(!op.prunable());
    }

    #[test]
    fn shape_inference_conv() {
        let op = Op::conv(7, 7, 3, 64, 2, 3);
        let s = Shape::nhwc(1, 224, 224, 3);
        assert_eq!(op.infer_shape(&[&s]), Shape::nhwc(1, 112, 112, 64));
    }

    #[test]
    fn shape_inference_concat() {
        let a = Shape::nhwc(1, 8, 8, 16);
        let b = Shape::nhwc(1, 8, 8, 32);
        assert_eq!(Op::Concat.infer_shape(&[&a, &b]), Shape::nhwc(1, 8, 8, 48));
    }

    #[test]
    fn flops_conv_known() {
        // 3x3x64->64 conv on 56x56: 2 * 56*56*64 * 3*3*64
        let op = Op::conv(3, 3, 64, 64, 1, 1);
        let inp = Shape::nhwc(1, 56, 56, 64);
        let out = op.infer_shape(&[&inp]);
        assert_eq!(op.flops(&[&inp], &out), 2 * 56 * 56 * 64 * 9 * 64);
    }

    #[test]
    fn bn_params() {
        assert_eq!(Op::BatchNorm { c: 32 }.aux_params(), 128);
    }

    #[test]
    fn fc_shape() {
        let op = Op::FullyConnected { cin: 400, cout: 120, bias: true };
        assert_eq!(op.infer_shape(&[&Shape::vec2(8, 400)]), Shape::vec2(8, 120));
        assert_eq!(op.weight_count(), 48_000);
        assert_eq!(op.aux_params(), 120);
    }
}
