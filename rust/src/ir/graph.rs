//! Dataflow graph: nodes in topological insertion order (builders append
//! only), with shape inference, per-node work accounting and rewrite
//! support for the compiler passes.

use super::ops::Op;
use super::shape::Shape;
use crate::error::CadnnError;

pub type NodeId = usize;

#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    /// Inferred output shape (filled by `Graph::add`).
    pub shape: Shape,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub input: NodeId,
    pub output: NodeId,
}

impl Graph {
    pub fn new(name: &str, input_shape: Shape) -> Self {
        let input = Node {
            id: 0,
            name: "input".into(),
            op: Op::Input { shape: input_shape.clone() },
            inputs: vec![],
            shape: input_shape,
        };
        Graph { name: name.into(), nodes: vec![input], input: 0, output: 0 }
    }

    /// Append a node; infers its shape; returns its id. The output marker
    /// follows the last added node.
    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let shapes: Vec<&Shape> = inputs.iter().map(|&i| &self.nodes[i].shape).collect();
        let shape = op.infer_shape(&shapes);
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.into(), op, inputs, shape });
        self.output = id;
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total trainable weights (the paper's "Size(M)" with f32 = 4 bytes
    /// is `(weights + aux) * 4 / 1e6`).
    pub fn weight_count(&self) -> usize {
        self.nodes.iter().map(|n| n.op.weight_count()).sum()
    }

    pub fn aux_param_count(&self) -> usize {
        self.nodes.iter().map(|n| n.op.aux_params()).sum()
    }

    pub fn param_count(&self) -> usize {
        self.weight_count() + self.aux_param_count()
    }

    /// Model size in MB at f32, the paper's Table 2 convention.
    pub fn size_mb(&self) -> f64 {
        self.param_count() as f64 * 4.0 / 1e6
    }

    /// Count of *weight layers* (conv / dwconv / fc) — the layer-count
    /// convention we report against Table 2 (documented in EXPERIMENTS.md).
    pub fn weight_layer_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.op.weight_count() > 0)
            .count()
    }

    /// Total forward FLOPs.
    pub fn flops(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| {
                let ins: Vec<&Shape> = n.inputs.iter().map(|&i| &self.nodes[i].shape).collect();
                n.op.flops(&ins, &n.shape)
            })
            .sum()
    }

    /// Users (consumers) of each node.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Validate topological invariants: inputs precede users, node names
    /// are unique, shapes are consistent under re-inference, single entry
    /// node. Diagnostics name the participating nodes (not just their
    /// ids) so errors over parsed `.cadnn` models stay actionable.
    pub fn validate(&self) -> Result<(), CadnnError> {
        let invalid = |reason: String| CadnnError::InvalidGraph {
            graph: self.name.clone(),
            reason,
        };
        if self.nodes.is_empty() {
            return Err(invalid("empty graph".into()));
        }
        if !matches!(self.nodes[0].op, Op::Input { .. }) {
            return Err(invalid("node 0 must be Input".into()));
        }
        let mut seen: std::collections::BTreeMap<&str, NodeId> = Default::default();
        for n in &self.nodes {
            if let Some(&first) = seen.get(n.name.as_str()) {
                return Err(invalid(format!(
                    "duplicate node name '{}' (nodes {first} and {})",
                    n.name, n.id
                )));
            }
            seen.insert(&n.name, n.id);
        }
        for n in &self.nodes {
            if n.id >= self.nodes.len() {
                return Err(invalid(format!("node {} id out of range", n.name)));
            }
            for &i in &n.inputs {
                if i >= n.id {
                    // append-only ids make any back-reference to self or a
                    // later node the cycle/forward-edge case; name both
                    // endpoints when the target exists
                    let target = self
                        .nodes
                        .get(i)
                        .map(|t| format!("'{}' ({i})", t.name))
                        .unwrap_or_else(|| format!("out-of-range id {i}"));
                    return Err(invalid(format!(
                        "node '{}' ({}) uses input {target} that does not precede it \
                         (cycle or forward edge)",
                        n.name, n.id
                    )));
                }
            }
            if n.id > 0 && n.inputs.is_empty() && !matches!(n.op, Op::Input { .. }) {
                return Err(invalid(format!("node '{}' has no inputs", n.name)));
            }
            let ins: Vec<&Shape> = n.inputs.iter().map(|&i| &self.nodes[i].shape).collect();
            let inferred = n.op.infer_shape(&ins);
            if inferred != n.shape {
                return Err(invalid(format!(
                    "node '{}' shape {} != inferred {}",
                    n.name, n.shape, inferred
                )));
            }
        }
        if self.output >= self.nodes.len() {
            return Err(invalid("output id out of range".into()));
        }
        Ok(())
    }

    /// This graph rebuilt at a different input batch size (leading input
    /// dimension), with every shape re-inferred — how file-defined models
    /// (`.cadnn`, a single fixed-batch graph on disk) get batch variants.
    /// Post-pass graphs containing [`Op::Gemm`] bake the batch into `m` /
    /// `out_shape`, so they only support the batch they were lowered at.
    pub fn with_batch(&self, batch: usize) -> Result<Graph, CadnnError> {
        if batch == 0 {
            return Err(CadnnError::config("batch size must be nonzero"));
        }
        let in_shape = &self.nodes[0].shape;
        if in_shape.rank() == 0 {
            return Err(CadnnError::config(format!(
                "graph '{}' has a rank-0 input; no batch axis to rewrite",
                self.name
            )));
        }
        if in_shape.0[0] == batch {
            return Ok(self.clone());
        }
        if self.nodes.iter().any(|n| matches!(n.op, Op::Gemm { .. })) {
            return Err(CadnnError::config(format!(
                "graph '{}' contains lowered Gemm nodes that fix batch {}; \
                 rebatch the pre-pass graph instead",
                self.name, in_shape.0[0]
            )));
        }
        let mut dims = in_shape.0.clone();
        dims[0] = batch;
        let mut g = Graph::new(&self.name, Shape(dims));
        g.nodes[0].name = self.nodes[0].name.clone();
        for n in self.nodes.iter().skip(1) {
            g.add(n.name.clone(), n.op.clone(), n.inputs.clone());
        }
        g.output = self.output;
        Ok(g)
    }

    /// Per-op-kind FLOP histogram (used by reports and the cost model).
    pub fn flops_by_kind(&self) -> Vec<(&'static str, u64)> {
        let mut map: std::collections::BTreeMap<&'static str, u64> = Default::default();
        for n in &self.nodes {
            let ins: Vec<&Shape> = n.inputs.iter().map(|&i| &self.nodes[i].shape).collect();
            *map.entry(n.op.name()).or_default() += n.op.flops(&ins, &n.shape);
        }
        map.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ops::{ActKind, PoolKind};

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", Shape::nhwc(1, 8, 8, 3));
        let c = g.add(
            "conv",
            Op::conv(3, 3, 3, 8, 1, 1),
            vec![0],
        );
        let b = g.add("bn", Op::BatchNorm { c: 8 }, vec![c]);
        let r = g.add("relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
        let p = g.add("pool", Op::Pool { kind: PoolKind::Max, k: 2, stride: 2, padding: 0 }, vec![r]);
        let f = g.add("flat", Op::Flatten, vec![p]);
        g.add("fc", Op::FullyConnected { cin: 128, cout: 10, bias: true }, vec![f]);
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.nodes.last().unwrap().shape, Shape::vec2(1, 10));
    }

    #[test]
    fn weight_accounting() {
        let g = tiny();
        assert_eq!(g.weight_count(), 3 * 3 * 3 * 8 + 128 * 10);
        assert_eq!(g.aux_param_count(), 4 * 8 + 10);
        assert_eq!(g.weight_layer_count(), 2);
    }

    #[test]
    fn flops_positive_and_dominated_by_conv() {
        let g = tiny();
        let by_kind = g.flops_by_kind();
        let conv: u64 = by_kind.iter().filter(|(k, _)| *k == "conv2d").map(|(_, v)| *v).sum();
        assert!(conv > 0);
        assert!(g.flops() >= conv);
    }

    #[test]
    fn validate_rejects_forward_edges() {
        let mut g = tiny();
        // manually corrupt: make node 1 depend on node 3
        g.nodes[1].inputs = vec![3];
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_duplicate_names() {
        let mut g = tiny();
        g.nodes[3].name = "conv".into();
        let err = g.validate().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate node name 'conv'"), "{msg}");
        assert!(msg.contains("nodes 1 and 3"), "{msg}");
    }

    #[test]
    fn forward_edge_diagnostic_names_both_nodes() {
        let mut g = tiny();
        g.nodes[1].inputs = vec![3];
        let msg = g.validate().unwrap_err().to_string();
        assert!(msg.contains("node 'conv' (1)"), "{msg}");
        assert!(msg.contains("'relu' (3)"), "{msg}");
        assert!(msg.contains("cycle or forward edge"), "{msg}");
    }

    #[test]
    fn with_batch_rebuilds_shapes() {
        let g = tiny();
        let g4 = g.with_batch(4).unwrap();
        assert!(g4.validate().is_ok());
        assert_eq!(g4.nodes[0].shape, Shape::nhwc(4, 8, 8, 3));
        assert_eq!(g4.nodes.last().unwrap().shape, Shape::vec2(4, 10));
        assert_eq!(g4.len(), g.len());
        assert_eq!(g4.with_batch(4).unwrap(), g4, "same batch is identity");
        assert!(g.with_batch(0).is_err());
    }

    #[test]
    fn with_batch_rejects_lowered_gemm() {
        let mut g = Graph::new("lowered", Shape::nhwc(1, 4, 4, 8));
        g.add(
            "g",
            Op::Gemm {
                m: 16,
                k: 8,
                n: 8,
                act: ActKind::None,
                fused_epilogue: false,
                out_shape: Shape::nhwc(1, 4, 4, 8),
            },
            vec![0],
        );
        assert!(g.with_batch(2).is_err());
    }

    #[test]
    fn consumers_inverse_of_inputs() {
        let g = tiny();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![1]); // input -> conv
        assert_eq!(cons[1], vec![2]); // conv -> bn
    }
}
