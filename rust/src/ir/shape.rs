//! Tensor shapes (NHWC activations).

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn nhwc(n: usize, h: usize, w: usize, c: usize) -> Self {
        Shape(vec![n, h, w, c])
    }

    pub fn vec2(n: usize, d: usize) -> Self {
        Shape(vec![n, d])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn bytes_f32(&self) -> usize {
        self.numel() * 4
    }

    pub fn n(&self) -> usize {
        self.0[0]
    }

    pub fn h(&self) -> usize {
        debug_assert_eq!(self.rank(), 4);
        self.0[1]
    }

    pub fn w(&self) -> usize {
        debug_assert_eq!(self.rank(), 4);
        self.0[2]
    }

    pub fn c(&self) -> usize {
        *self.0.last().unwrap()
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Conv output spatial size: floor((in + 2p - k) / s) + 1.
pub fn conv_out(input: usize, k: usize, stride: usize, pad: usize) -> usize {
    debug_assert!(input + 2 * pad >= k, "conv window larger than input");
    (input + 2 * pad - k) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_bytes() {
        let s = Shape::nhwc(2, 8, 8, 3);
        assert_eq!(s.numel(), 384);
        assert_eq!(s.bytes_f32(), 1536);
        assert_eq!((s.n(), s.h(), s.w(), s.c()), (2, 8, 8, 3));
    }

    #[test]
    fn conv_out_matches_convention() {
        assert_eq!(conv_out(224, 7, 2, 3), 112); // ResNet-50 stem
        assert_eq!(conv_out(28, 5, 1, 2), 28); // LeNet c1 'same'
        assert_eq!(conv_out(14, 5, 1, 0), 10); // LeNet c2 'valid'
        assert_eq!(conv_out(112, 3, 2, 1), 56);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::nhwc(1, 2, 3, 4).to_string(), "[1,2,3,4]");
    }
}
