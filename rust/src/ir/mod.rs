//! DNN graph IR.
//!
//! The Rust side reasons about the *exact* paper architectures
//! (ResNet-50, MobileNet-V1/V2, Inception-V3, plus the §3 pruning
//! subjects) as dataflow graphs of typed layer ops. The compiler passes
//! (`passes/`), the compression accounting (`compress/`), the cost model
//! (`costmodel/`) and the native executor (`exec/`) all operate on this
//! IR. Tensors are NHWC; conv weights are HWIO (matching the Python L2
//! models and the Pallas kernels).

pub mod graph;
pub mod ops;
pub mod shape;

pub use graph::{Graph, Node, NodeId};
pub use ops::{ActKind, Op, PoolKind};
pub use shape::Shape;
