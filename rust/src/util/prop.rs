//! proptest-lite: a tiny property-testing harness (proptest is not
//! available offline). Generates `CASES` random inputs from a seeded RNG,
//! runs the property, and on failure retries with a linear shrink pass
//! over integer parameters to report a smaller counterexample.

use super::rng::Rng;

pub const CASES: usize = 128;

/// Run `prop(rng)` for CASES seeds; panics (with the failing seed) on the
/// first failure so the case is reproducible.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    check_n(name, CASES, prop)
}

pub fn check_n<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Assert equality with debug formatting.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // interior mutability via Cell to count invocations
        let cell = std::cell::Cell::new(0usize);
        check_n("trivial", 10, |_rng| {
            cell.set(cell.get() + 1);
            Ok(())
        });
        count += cell.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check_n("fails", 10, |rng| {
            let v = rng.below(100);
            if v < 1000 {
                Err(format!("v = {v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn properties_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check_n("collect", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check_n("collect", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
