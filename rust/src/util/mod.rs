//! Foundation substrate: everything a framework needs and this
//! environment's crate set doesn't provide (no serde / tokio / criterion /
//! proptest offline), built from scratch per the reproduction scope rules.

pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

/// Monotonic stopwatch used by benches and the coordinator metrics.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: std::time::Instant::now() }
    }
    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_us() >= 1000.0);
        assert!(sw.elapsed_ms() >= 1.0);
    }
}
