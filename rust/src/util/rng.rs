//! xorshift128+ PRNG: deterministic, fast, no external crates.
//! Used by workload generators, weight initialization and proptest-lite.

#[derive(Debug, Clone)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to expand the seed into two non-zero words
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next().max(1);
        let s1 = next().max(1);
        Self { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (for Poisson arrival gaps).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Fill a slice with N(0, scale) weights.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_mean_near_zero() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.normal()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn exp_mean_near_inverse_rate() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
