//! Leveled stderr logger with an env filter (`CADNN_LOG=debug|info|warn`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // Info
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("CADNN_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
