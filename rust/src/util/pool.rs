//! Fixed-size thread pool (no tokio offline). Owns worker threads fed by
//! an MPMC channel built on Mutex+Condvar; supports fire-and-forget jobs
//! and a scoped parallel-for used by the native kernels.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cadnn-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Run `f(chunk_index)` for each of `n` chunks in parallel, blocking
    /// until all complete. Implemented with scoped threads + an atomic
    /// work counter (work-stealing loop), so `f` may borrow locals.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        parallel_for_n(self.size, n, f)
    }
}

/// Scoped parallel-for with `threads` workers over `n` chunks.
pub fn parallel_for_n<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.min(n).max(1);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

static GLOBAL_POOL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
static REQUESTED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Hint the size of the global pool before first use (e.g. from
/// `EngineBuilder::threads`). Returns `false` if the pool already exists,
/// in which case the hint has no effect.
pub fn request_threads(n: usize) -> bool {
    REQUESTED_THREADS.store(n, Ordering::SeqCst);
    GLOBAL_POOL.get().is_none()
}

/// Global pool sized to the host (shared by kernels and benches), or to
/// the last `request_threads` hint made before first use.
pub fn global() -> &'static ThreadPool {
    GLOBAL_POOL.get_or_init(|| {
        let requested = REQUESTED_THREADS.load(Ordering::SeqCst);
        let n = if requested > 0 {
            requested
        } else {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4)
        };
        ThreadPool::new(n.min(16))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // drop blocks until queue drained? No: shutdown only stops when
        // queue is empty, so join-on-drop finishes the work.
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_all_chunks() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(37, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn parallel_for_blocks_until_done() {
        let pool = ThreadPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(16, |i| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..16).sum::<usize>());
    }

    #[test]
    fn global_pool_is_usable() {
        let sum = AtomicUsize::new(0);
        global().parallel_for(8, |i| {
            sum.fetch_add(i + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 36);
    }
}
