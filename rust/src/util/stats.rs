//! Latency/throughput statistics: percentiles, mean, a fixed-window
//! histogram, and a tiny measurement harness used by the benches
//! (criterion is not available offline).

/// Summary over a set of samples (microseconds, milliseconds — unit-free).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut s: Vec<f64> = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            // nearest-rank on the sorted array
            let idx = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
            s[idx.min(s.len() - 1)]
        };
        Some(Summary {
            count: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            min: s[0],
            p50: pct(50.0),
            p90: pct(90.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: *s.last().unwrap(),
        })
    }
}

/// Online percentile collector (stores samples; fine for bench scale).
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    samples: Vec<f64>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn summary(&self) -> Option<Summary> {
        Summary::from(&self.samples)
    }
    pub fn clear(&mut self) {
        self.samples.clear();
    }
}

/// Measure `f` after warmup: returns per-iteration wall time in
/// microseconds (median-of-runs is up to the caller via Summary).
pub fn measure_us<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    out
}

/// Adaptive measurement: repeat `f` until `min_total_us` wall time is
/// spent or `max_iters` is reached; returns per-iter microseconds.
pub fn measure_adaptive_us<F: FnMut()>(min_total_us: f64, max_iters: usize, mut f: F) -> Vec<f64> {
    // one warmup
    f();
    let mut out = Vec::new();
    let t_start = std::time::Instant::now();
    while out.len() < max_iters
        && (out.len() < 3 || t_start.elapsed().as_secs_f64() * 1e6 < min_total_us)
    {
        let t0 = std::time::Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from(&[]).is_none());
    }

    #[test]
    fn percentiles_monotone() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&v).unwrap();
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p99 - 989.0).abs() <= 1.0);
    }

    #[test]
    fn recorder_accumulates() {
        let mut r = Recorder::new();
        assert!(r.summary().is_none());
        r.record(2.0);
        r.record(4.0);
        assert_eq!(r.len(), 2);
        assert!((r.summary().unwrap().mean - 3.0).abs() < 1e-12);
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn measure_returns_requested_iters() {
        let v = measure_us(1, 5, || { std::hint::black_box(1 + 1); });
        assert_eq!(v.len(), 5);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn measure_adaptive_terminates() {
        let v = measure_adaptive_us(100.0, 50, || {
            std::thread::sleep(std::time::Duration::from_micros(30))
        });
        assert!(v.len() >= 3 && v.len() <= 50);
    }
}
