//! Minimal JSON parser/writer (no serde available offline).
//!
//! Supports the full JSON grammar: null, booleans, f64 numbers, strings
//! with escapes (incl. \uXXXX BMP), arrays, objects. Object key order is
//! preserved (insertion order) so round-trips are stable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: array of numbers -> Vec<f32>.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    /// Convenience: array of numbers -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line rendering (no newlines or indentation) — the JSONL
    /// telemetry stream needs one document per line.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !kv.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Helper to build objects tersely.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Sorted-key map view of an object (for comparisons in tests).
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(kv) => kv.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models": [{"name": "lenet5", "batch": 1, "acc": 0.99}], "ok": true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }
}
