//! Serving metrics: per-request latency percentiles, batch utilization,
//! throughput.

use crate::util::stats::{Recorder, Summary};
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latency: Recorder,
    /// exec time per batch run
    exec: Recorder,
    pub requests: u64,
    pub batches: u64,
    /// sum over runs of (used slots) and (total slots) — padding waste.
    pub used_slots: u64,
    pub total_slots: u64,
    /// requests answered with a backend-error outcome.
    pub backend_errors: u64,
}

impl Metrics {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latency: Recorder::new(),
            exec: Recorder::new(),
            requests: 0,
            batches: 0,
            used_slots: 0,
            total_slots: 0,
            backend_errors: 0,
        }
    }

    pub fn record_request(&mut self, latency_us: f64) {
        self.latency.record(latency_us);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, batch: usize, used: usize, exec_us: f64) {
        self.batches += 1;
        self.used_slots += used as u64;
        self.total_slots += batch as u64;
        self.exec.record(exec_us);
    }

    /// Count requests that received an explicit backend-error response.
    pub fn record_errors(&mut self, n: u64) {
        self.backend_errors += n;
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latency.summary()
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        self.exec.summary()
    }

    /// Requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Fraction of executed batch slots carrying real requests.
    pub fn batch_utilization(&self) -> f64 {
        if self.total_slots == 0 {
            return 1.0;
        }
        self.used_slots as f64 / self.total_slots as f64
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} batches={} errors={} throughput={:.1} req/s batch_util={:.0}%\n",
            self.requests,
            self.batches,
            self.backend_errors,
            self.throughput_rps(),
            self.batch_utilization() * 100.0
        ));
        if let Some(s) = self.latency_summary() {
            out.push_str(&format!(
                "latency  p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = self.exec_summary() {
            out.push_str(&format!(
                "exec     p50={:.1}ms mean={:.1}ms\n",
                s.p50 / 1e3,
                s.mean / 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_request(1000.0);
        m.record_request(3000.0);
        m.record_batch(4, 2, 500.0);
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        assert_eq!(m.batch_utilization(), 0.5);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 2);
        let rpt = m.report();
        assert!(rpt.contains("requests=2"));
        assert!(rpt.contains("latency"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert_eq!(m.batch_utilization(), 1.0);
        assert!(m.report().contains("requests=0"));
    }
}
