//! Batch-size selection among the compiled (shape-static) batch variants.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Smallest compiled batch >= pending (pads the remainder). Wastes
    /// some compute, minimizes queue latency.
    PadToFit,
    /// Largest compiled batch <= pending (runs multiple rounds). No
    /// padding waste, but the tail waits.
    Greedy,
}

/// Choose the compiled batch for `pending` requests from `available`
/// (ascending batch sizes, non-empty).
pub fn pick_batch(pending: usize, available: &[usize], policy: BatchPolicy) -> usize {
    debug_assert!(!available.is_empty());
    debug_assert!(available.windows(2).all(|w| w[0] < w[1]), "must be ascending");
    let pending = pending.max(1);
    match policy {
        BatchPolicy::PadToFit => available
            .iter()
            .copied()
            .find(|&b| b >= pending)
            .unwrap_or(*available.last().unwrap()),
        BatchPolicy::Greedy => available
            .iter()
            .copied()
            .rev()
            .find(|&b| b <= pending)
            .unwrap_or(available[0]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    const AVAIL: [usize; 3] = [1, 4, 8];

    #[test]
    fn pad_to_fit_picks_smallest_covering() {
        assert_eq!(pick_batch(1, &AVAIL, BatchPolicy::PadToFit), 1);
        assert_eq!(pick_batch(2, &AVAIL, BatchPolicy::PadToFit), 4);
        assert_eq!(pick_batch(4, &AVAIL, BatchPolicy::PadToFit), 4);
        assert_eq!(pick_batch(5, &AVAIL, BatchPolicy::PadToFit), 8);
        assert_eq!(pick_batch(50, &AVAIL, BatchPolicy::PadToFit), 8);
    }

    #[test]
    fn greedy_picks_largest_fitting() {
        assert_eq!(pick_batch(1, &AVAIL, BatchPolicy::Greedy), 1);
        assert_eq!(pick_batch(3, &AVAIL, BatchPolicy::Greedy), 1);
        assert_eq!(pick_batch(4, &AVAIL, BatchPolicy::Greedy), 4);
        assert_eq!(pick_batch(7, &AVAIL, BatchPolicy::Greedy), 4);
        assert_eq!(pick_batch(9, &AVAIL, BatchPolicy::Greedy), 8);
    }

    #[test]
    fn zero_pending_treated_as_one() {
        assert_eq!(pick_batch(0, &AVAIL, BatchPolicy::PadToFit), 1);
        assert_eq!(pick_batch(0, &AVAIL, BatchPolicy::Greedy), 1);
    }

    #[test]
    fn non_contiguous_batch_sets() {
        // gaps and a floor above 1 — e.g. a manifest compiled at [2, 3, 7]
        let avail = [2usize, 3, 7];
        // PadToFit: smallest covering, or the largest when none covers
        assert_eq!(pick_batch(1, &avail, BatchPolicy::PadToFit), 2);
        assert_eq!(pick_batch(2, &avail, BatchPolicy::PadToFit), 2);
        assert_eq!(pick_batch(3, &avail, BatchPolicy::PadToFit), 3);
        assert_eq!(pick_batch(4, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(6, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(7, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(100, &avail, BatchPolicy::PadToFit), 7);
        // Greedy: largest fitting, or the smallest when none fits
        assert_eq!(pick_batch(1, &avail, BatchPolicy::Greedy), 2);
        assert_eq!(pick_batch(2, &avail, BatchPolicy::Greedy), 2);
        assert_eq!(pick_batch(4, &avail, BatchPolicy::Greedy), 3);
        assert_eq!(pick_batch(6, &avail, BatchPolicy::Greedy), 3);
        assert_eq!(pick_batch(7, &avail, BatchPolicy::Greedy), 7);
        assert_eq!(pick_batch(9, &avail, BatchPolicy::Greedy), 7);
    }

    #[test]
    fn singleton_batch_set() {
        for pending in [0usize, 1, 5, 40] {
            assert_eq!(pick_batch(pending, &[4], BatchPolicy::PadToFit), 4);
            assert_eq!(pick_batch(pending, &[4], BatchPolicy::Greedy), 4);
        }
    }

    #[test]
    fn prop_pick_batch_invariants() {
        prop::check("pick_batch invariants", |rng: &mut Rng| {
            // random ascending available set
            let mut avail = vec![1usize];
            let mut v = 1;
            for _ in 0..rng.range(0, 4) {
                v *= rng.range(2, 4);
                avail.push(v);
            }
            let pending = rng.range(0, 40);
            for policy in [BatchPolicy::PadToFit, BatchPolicy::Greedy] {
                let b = pick_batch(pending, &avail, policy);
                prop_assert!(avail.contains(&b), "picked {} not available", b);
                // progress guarantee: the flush loop always drains >= 1
                prop_assert!(b >= 1, "no progress");
                if policy == BatchPolicy::PadToFit && pending.max(1) <= *avail.last().unwrap() {
                    prop_assert!(
                        b >= pending.max(1),
                        "pad-to-fit must cover pending: {} < {}",
                        b,
                        pending
                    );
                }
                if policy == BatchPolicy::Greedy && pending >= 1 {
                    prop_assert!(
                        b <= pending.max(1) || b == avail[0],
                        "greedy overshoot: {} > {}",
                        b,
                        pending
                    );
                }
            }
            Ok(())
        });
    }
}
