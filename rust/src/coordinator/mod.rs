//! Serving coordinator: request queue -> dynamic batcher -> PJRT worker.
//!
//! The L3 contribution rendered for serving: clients submit single-image
//! requests; the batcher coalesces them (bounded by `max_batch` and
//! `max_wait_us`) and picks among the AOT batch variants (PJRT programs
//! are shape-static, so "dynamic batching" = choosing the best-fitting
//! compiled batch and padding the remainder). Latency percentiles and
//! throughput are recorded per request.

pub mod batcher;
pub mod metrics;

pub use batcher::{pick_batch, BatchPolicy};
pub use metrics::Metrics;

use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: String,
    pub model: String,
    pub variant: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            model: "lenet5".into(),
            variant: "dense".into(),
            max_batch: 8,
            max_wait_us: 2_000,
            policy: BatchPolicy::PadToFit,
        }
    }
}

/// One inference request (flat NHWC image) with its reply channel.
struct Request {
    id: u64,
    input: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    /// end-to-end latency (enqueue -> reply), microseconds
    pub latency_us: f64,
    /// batch this request rode in
    pub batch: usize,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Client handle: submit images, await responses.
pub struct Coordinator {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<Metrics>>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    pub input_len: usize,
    pub classes: usize,
}

impl Coordinator {
    /// Start the worker thread: it opens the runtime, compiles the model
    /// variants, then serves until shutdown.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let m2 = metrics.clone();
        // probe the manifest up front for input geometry (fail fast)
        let text = std::fs::read_to_string(format!("{}/manifest.json", cfg.artifacts_dir))?;
        let manifest = crate::runtime::Manifest::parse(&text)?;
        let entry = manifest
            .models
            .iter()
            .find(|e| e.name == cfg.model && e.variant == cfg.variant && e.batch == 1)
            .ok_or_else(|| anyhow!("no batch-1 artifact for {}/{}", cfg.model, cfg.variant))?
            .clone();
        let input_len: usize = entry.input_shape.iter().product();
        let classes = entry.classes;

        let cfg2 = cfg.clone();
        // readiness handshake: the worker compiles the PJRT executables
        // before serving; block here so client latency measurements see
        // steady-state, and so load errors surface at start().
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("cadnn-coordinator".into())
            .spawn(move || worker_loop(cfg2, rx, m2, ready_tx))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow!("coordinator worker failed to start: {e}")),
            Err(_) => return Err(anyhow!("coordinator worker died during startup")),
        }
        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            worker: Some(worker),
            input_len,
            classes,
        })
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<Response>> {
        if input.len() != self.input_len {
            return Err(anyhow!(
                "input length {} != expected {}",
                input.len(),
                self.input_len
            ));
        }
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request { id, input, enqueued: Instant::now(), reply: rtx }))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| anyhow!("worker dropped request"))
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    ready: Sender<Result<(), String>>,
) -> Result<()> {
    // PJRT objects are created inside the worker thread (no Send bound).
    let init = (|| -> Result<Runtime> {
        let mut rt = Runtime::open(&cfg.artifacts_dir)?;
        rt.load(&cfg.model, &cfg.variant)?;
        Ok(rt)
    })();
    let rt = match init {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return Err(e);
        }
    };
    let batches = rt.batches(&cfg.model, &cfg.variant);
    if batches.is_empty() {
        return Err(anyhow!("no batch variants loaded"));
    }
    let per_image = rt
        .get(&cfg.model, &cfg.variant, batches[0])
        .map(|m| m.entry.input_shape.iter().skip(1).product::<usize>())
        .unwrap();
    let classes = rt
        .get(&cfg.model, &cfg.variant, batches[0])
        .map(|m| m.entry.classes)
        .unwrap();

    let mut queue: Vec<Request> = Vec::new();
    loop {
        // fill the queue: block for the first request, then drain for up
        // to max_wait_us or until max_batch requests are pending.
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) | Err(_) => return Ok(()),
            }
        }
        // drain whatever is already queued (a burst that arrived while
        // the previous batch executed) without waiting
        while queue.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    flush(&rt, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(_) => break,
            }
        }
        let deadline = queue[0].enqueued + Duration::from_micros(cfg.max_wait_us);
        while queue.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    flush(&rt, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(_) => {
                    flush(&rt, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
            }
        }
        flush(&rt, &cfg, &mut queue, &batches, per_image, classes, &metrics);
    }
}

/// Execute and reply to as many queued requests as one batch allows.
fn flush(
    rt: &Runtime,
    cfg: &CoordinatorConfig,
    queue: &mut Vec<Request>,
    batches: &[usize],
    per_image: usize,
    classes: usize,
    metrics: &Arc<Mutex<Metrics>>,
) {
    while !queue.is_empty() {
        let b = pick_batch(queue.len().min(cfg.max_batch), batches, cfg.policy);
        let take = b.min(queue.len());
        let mut input = vec![0.0f32; b * per_image];
        for (i, r) in queue.iter().take(take).enumerate() {
            input[i * per_image..(i + 1) * per_image].copy_from_slice(&r.input);
        }
        let model = rt
            .get(&cfg.model, &cfg.variant, b)
            .expect("picked batch must be loaded");
        let t0 = Instant::now();
        let out = match model.run(&input) {
            Ok(o) => o,
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Error,
                    "coordinator",
                    format_args!("execute failed: {e}"),
                );
                // drop the affected requests (reply channels close)
                queue.drain(..take);
                continue;
            }
        };
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut m = metrics.lock().unwrap();
        m.record_batch(b, take, exec_us);
        for (i, r) in queue.drain(..take).enumerate() {
            let latency_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            m.record_request(latency_us);
            let _ = r.reply.send(Response {
                id: r.id,
                logits: out[i * classes..(i + 1) * classes].to_vec(),
                latency_us,
                batch: b,
            });
        }
    }
}
