//! **Deprecated shim** — the single-model serving coordinator, kept as a
//! thin compatibility layer over [`crate::serve`].
//!
//! The coordinator predates the multi-model [`crate::serve::Server`]:
//! it serves exactly one backend under the registry name `"default"`
//! and exposes the original `submit`/`infer`/`metrics` surface. New
//! code should use `serve` directly — it adds named multi-model routing,
//! per-request deadlines and top-k, planner-informed batch scheduling
//! ([`crate::planner::ExecPlan::cost_at`]), and per-model stats
//! snapshots. See `docs/SERVING.md` and the `docs/API.md` migration
//! table.
//!
//! Behavior notes for legacy callers: responses are
//! [`crate::serve::ServeResponse`] (re-exported here as [`Response`]) —
//! same fields as before plus `model`/`topk`; [`ServeError`] gained a
//! `Deadline` variant (never produced through this shim, which sets no
//! deadlines); batch-size choice upgrades from the plain policy rule to
//! the planner-informed scheduler once the backend's cost model
//! calibrates, falling back to the configured [`BatchPolicy`] otherwise.

/// Legacy path: `coordinator::batcher::{pick_batch, BatchPolicy}`.
pub mod batcher {
    pub use crate::serve::scheduler::{pick_batch, BatchPolicy};
}
/// Legacy path: `coordinator::metrics::Metrics`.
pub mod metrics {
    pub use crate::serve::metrics::{Metrics, MetricsSnapshot};
}

pub use crate::serve::{pick_batch, BatchPolicy, Metrics, ServeError};
/// The coordinator's response type is the serve response.
pub use crate::serve::ServeResponse as Response;

use crate::api::{ArtifactBackend, Backend};
use crate::error::CadnnError;
use crate::serve::{QueueConfig, ServeRequest, Server};
use anyhow::{anyhow, Result};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// The one registry name the shim serves under.
const MODEL: &str = "default";

/// Batching knobs, independent of where the model comes from.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub policy: BatchPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait_us: 2_000, policy: BatchPolicy::PadToFit }
    }
}

impl BatcherConfig {
    fn queue(&self) -> QueueConfig {
        QueueConfig {
            max_batch: self.max_batch,
            max_wait_us: self.max_wait_us,
            fallback: self.policy,
            planned: true,
            ..QueueConfig::default()
        }
    }
}

/// Artifact-serving configuration (the original entry point, kept for
/// the AOT path; native engines use [`Coordinator::serve_engine`]).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: String,
    pub model: String,
    pub variant: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            model: "lenet5".into(),
            variant: "dense".into(),
            max_batch: 8,
            max_wait_us: 2_000,
            policy: BatchPolicy::PadToFit,
        }
    }
}

/// Client handle: submit images, await responses. A single-model
/// [`Server`] underneath.
pub struct Coordinator {
    server: Server,
    /// Live metrics handle; recording and reading are both lock-free
    /// (`&self` methods on [`Metrics`]), so this never contends with
    /// the worker. The pre-obs `Arc<Mutex<Metrics>>` is gone — see the
    /// `docs/API.md` migration table.
    pub metrics: Arc<Metrics>,
    pub input_len: usize,
    pub classes: usize,
}

impl Coordinator {
    /// Serve a backend constructed *inside* the worker thread (required
    /// for backends whose handles are not `Send`, e.g. real PJRT). The
    /// call blocks until the backend is ready (or failed), so client
    /// latency measurements see steady state and load errors surface
    /// here.
    pub fn serve_with<F>(factory: F, cfg: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send + 'static,
    {
        let server = Server::builder()
            .backend_with(MODEL, factory, cfg.queue())
            .build()
            .map_err(|e| anyhow!("coordinator worker failed to start: {e}"))?;
        let metrics = server.metrics(MODEL).expect("default model registered");
        let input_len = server.input_len(MODEL).expect("default model registered");
        let classes = server.classes(MODEL).expect("default model registered");
        Ok(Coordinator { server, metrics, input_len, classes })
    }

    /// Serve an already-constructed backend.
    pub fn serve(backend: Box<dyn Backend + Send>, cfg: BatcherConfig) -> Result<Coordinator> {
        Self::serve_with(
            move || {
                let backend: Box<dyn Backend> = backend;
                Ok(backend)
            },
            cfg,
        )
    }

    /// Serve a (cheaply cloned) [`crate::api::Engine`] — the way to put
    /// the dynamic batcher in front of a natively-executed model, no
    /// artifacts directory required.
    pub fn serve_engine(engine: &crate::api::Engine, cfg: BatcherConfig) -> Result<Coordinator> {
        let engine = engine.clone();
        Self::serve_with(
            move || {
                let backend: Box<dyn Backend> = Box::new(engine);
                Ok(backend)
            },
            cfg,
        )
    }

    /// Start an artifact-serving worker: it opens the PJRT runtime,
    /// compiles the model's batch variants, then serves until shutdown.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let batcher = BatcherConfig {
            max_batch: cfg.max_batch,
            max_wait_us: cfg.max_wait_us,
            policy: cfg.policy,
        };
        Self::serve_with(
            move || {
                ArtifactBackend::open(&cfg.artifacts_dir, &cfg.model, &cfg.variant)
                    .map(|b| -> Box<dyn Backend> { Box::new(b) })
            },
            batcher,
        )
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<Response>> {
        if input.len() != self.input_len {
            return Err(anyhow!(
                "input length {} != expected {}",
                input.len(),
                self.input_len
            ));
        }
        self.server
            .submit(ServeRequest::new(MODEL, input))
            .map_err(|e| anyhow!("coordinator stopped: {e}"))
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| anyhow!("worker dropped request"))
    }

    pub fn shutdown(self) -> Result<()> {
        self.server.shutdown().map_err(|e| anyhow!("{e}"))
    }
}
