//! Serving coordinator: request queue -> dynamic batcher -> any backend.
//!
//! Clients submit single-image requests; the batcher coalesces them
//! (bounded by `max_batch` and `max_wait_us`) and picks among the
//! backend's batch variants (programs are shape-static, so "dynamic
//! batching" = choosing the best-fitting batch and padding the
//! remainder). Latency percentiles and throughput are recorded per
//! request.
//!
//! The worker serves any [`Backend`] — a natively-executed
//! [`crate::api::Engine`] via [`Coordinator::serve_engine`], AOT PJRT
//! artifacts via [`Coordinator::start`], or anything else via
//! [`Coordinator::serve_with`] (the factory runs *inside* the worker
//! thread, accommodating backends whose handles are not `Send`).
//!
//! Error semantics: a request that fails in the backend receives an
//! explicit [`ServeError::Backend`] response, while coordinator shutdown
//! closes the reply channel (`RecvError`) — clients can tell the two
//! apart.

pub mod batcher;
pub mod metrics;

pub use batcher::{pick_batch, BatchPolicy};
pub use metrics::Metrics;

use crate::api::{ArtifactBackend, Backend};
use crate::error::CadnnError;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching knobs, independent of where the model comes from.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub policy: BatchPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait_us: 2_000, policy: BatchPolicy::PadToFit }
    }
}

/// Artifact-serving configuration (the original entry point, kept for
/// the AOT path; native engines use [`Coordinator::serve_engine`]).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: String,
    pub model: String,
    pub variant: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            model: "lenet5".into(),
            variant: "dense".into(),
            max_batch: 8,
            max_wait_us: 2_000,
            policy: BatchPolicy::PadToFit,
        }
    }
}

/// One inference request (flat NHWC image) with its reply channel.
struct Request {
    id: u64,
    input: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Response>,
}

/// Why a request failed while the coordinator stayed alive. (Shutdown is
/// signalled differently: the reply channel closes.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend rejected or failed the batch this request rode in.
    Backend(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Backend(msg) => write!(f, "backend error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Logits on success, or an explicit backend error.
    pub outcome: Result<Vec<f32>, ServeError>,
    /// end-to-end latency (enqueue -> reply), microseconds
    pub latency_us: f64,
    /// batch this request rode in
    pub batch: usize,
}

impl Response {
    /// Logits, if the request succeeded.
    pub fn logits(&self) -> Option<&[f32]> {
        self.outcome.as_ref().ok().map(|v| v.as_slice())
    }

    /// Consume into logits or the serve error.
    pub fn into_logits(self) -> Result<Vec<f32>, ServeError> {
        self.outcome
    }
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Client handle: submit images, await responses.
pub struct Coordinator {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<Metrics>>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    pub input_len: usize,
    pub classes: usize,
}

impl Coordinator {
    /// Serve a backend constructed *inside* the worker thread (required
    /// for backends whose handles are not `Send`, e.g. real PJRT). The
    /// call blocks until the backend is ready (or failed), so client
    /// latency measurements see steady state and load errors surface
    /// here.
    pub fn serve_with<F>(factory: F, cfg: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize), String>>();
        let worker = std::thread::Builder::new()
            .name("cadnn-coordinator".into())
            .spawn(move || worker_loop(factory, cfg, rx, m2, ready_tx))?;
        let (input_len, classes) = match ready_rx.recv() {
            Ok(Ok(geometry)) => geometry,
            Ok(Err(e)) => return Err(anyhow!("coordinator worker failed to start: {e}")),
            Err(_) => return Err(anyhow!("coordinator worker died during startup")),
        };
        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            worker: Some(worker),
            input_len,
            classes,
        })
    }

    /// Serve an already-constructed backend.
    pub fn serve(backend: Box<dyn Backend + Send>, cfg: BatcherConfig) -> Result<Coordinator> {
        Self::serve_with(
            move || {
                let backend: Box<dyn Backend> = backend;
                Ok(backend)
            },
            cfg,
        )
    }

    /// Serve a (cheaply cloned) [`crate::api::Engine`] — the way to put
    /// the dynamic batcher in front of a natively-executed model, no
    /// artifacts directory required.
    pub fn serve_engine(engine: &crate::api::Engine, cfg: BatcherConfig) -> Result<Coordinator> {
        let engine = engine.clone();
        Self::serve_with(
            move || {
                let backend: Box<dyn Backend> = Box::new(engine);
                Ok(backend)
            },
            cfg,
        )
    }

    /// Start an artifact-serving worker: it opens the PJRT runtime,
    /// compiles the model's batch variants, then serves until shutdown.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let batcher = BatcherConfig {
            max_batch: cfg.max_batch,
            max_wait_us: cfg.max_wait_us,
            policy: cfg.policy,
        };
        Self::serve_with(
            move || {
                ArtifactBackend::open(&cfg.artifacts_dir, &cfg.model, &cfg.variant)
                    .map(|b| -> Box<dyn Backend> { Box::new(b) })
            },
            batcher,
        )
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<Response>> {
        if input.len() != self.input_len {
            return Err(anyhow!(
                "input length {} != expected {}",
                input.len(),
                self.input_len
            ));
        }
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request { id, input, enqueued: Instant::now(), reply: rtx }))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| anyhow!("worker dropped request"))
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<F>(
    factory: F,
    cfg: BatcherConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    ready: Sender<Result<(usize, usize), String>>,
) -> Result<()>
where
    F: FnOnce() -> Result<Box<dyn Backend>, CadnnError>,
{
    // Backend objects are created inside the worker thread (no Send bound
    // on the backend itself, only on the factory).
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return Err(anyhow!("backend init failed: {e}"));
        }
    };
    let batches = backend.batch_sizes();
    if batches.is_empty() {
        let msg = "backend reports no batch variants".to_string();
        let _ = ready.send(Err(msg.clone()));
        return Err(anyhow!(msg));
    }
    let per_image: usize = backend.input_shape().iter().product();
    let classes = backend.classes();
    let _ = ready.send(Ok((per_image, classes)));
    let backend = backend.as_ref();

    let mut queue: Vec<Request> = Vec::new();
    loop {
        // fill the queue: block for the first request, then drain for up
        // to max_wait_us or until max_batch requests are pending.
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) | Err(_) => return Ok(()),
            }
        }
        // drain whatever is already queued (a burst that arrived while
        // the previous batch executed) without waiting
        while queue.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    flush(backend, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(_) => break,
            }
        }
        let deadline = queue[0].enqueued + Duration::from_micros(cfg.max_wait_us);
        while queue.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    flush(backend, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(_) => {
                    flush(backend, &cfg, &mut queue, &batches, per_image, classes, &metrics);
                    return Ok(());
                }
            }
        }
        flush(backend, &cfg, &mut queue, &batches, per_image, classes, &metrics);
    }
}

/// Execute and reply to as many queued requests as one batch allows.
fn flush(
    backend: &dyn Backend,
    cfg: &BatcherConfig,
    queue: &mut Vec<Request>,
    batches: &[usize],
    per_image: usize,
    classes: usize,
    metrics: &Arc<Mutex<Metrics>>,
) {
    while !queue.is_empty() {
        let b = pick_batch(queue.len().min(cfg.max_batch), batches, cfg.policy);
        let take = b.min(queue.len());
        let mut input = vec![0.0f32; b * per_image];
        for (i, r) in queue.iter().take(take).enumerate() {
            input[i * per_image..(i + 1) * per_image].copy_from_slice(&r.input);
        }
        let t0 = Instant::now();
        let out = match backend.run_batch(b, &input) {
            Ok(o) => o,
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Error,
                    "coordinator",
                    format_args!("execute failed: {e}"),
                );
                // answer the affected requests with an explicit backend
                // error so clients can distinguish this from shutdown
                // (where the reply channel just closes)
                let err = ServeError::Backend(e.to_string());
                let mut m = metrics.lock().unwrap();
                m.record_errors(take as u64);
                drop(m);
                for r in queue.drain(..take) {
                    let latency_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
                    let _ = r.reply.send(Response {
                        id: r.id,
                        outcome: Err(err.clone()),
                        latency_us,
                        batch: b,
                    });
                }
                continue;
            }
        };
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut m = metrics.lock().unwrap();
        m.record_batch(b, take, exec_us);
        for (i, r) in queue.drain(..take).enumerate() {
            let latency_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            m.record_request(latency_us);
            let _ = r.reply.send(Response {
                id: r.id,
                outcome: Ok(out[i * classes..(i + 1) * classes].to_vec()),
                latency_us,
                batch: b,
            });
        }
    }
}
