//! Exact-architecture model builders.
//!
//! Two families:
//! - **Evaluation subjects** (Figure 2 / Table 2): MobileNet-V1,
//!   MobileNet-V2, Inception-V3, ResNet-50 at 224x224(299 for Inception)
//!   ImageNet geometry. Parameter counts are pinned against the canonical
//!   values in unit tests (Table 2's Size(M) = params * 4 bytes).
//! - **§3 pruning subjects**: LeNet-5, AlexNet, VGG-16, ResNet-18, used
//!   by the compression accounting.
//!
//! Builders emit *pre-pass* graphs (Conv/BN/Act as separate nodes) —
//! exactly what a model zoo hands a mobile framework — so the paper's
//! fusion/transformation passes have real work to do.

pub mod classic;
pub mod inception;
pub mod mobilenet;
pub mod resnet;

use crate::ir::Graph;

/// Figure 2 / Table 2 evaluation subjects.
pub const EVAL_MODELS: [&str; 4] = ["mobilenet_v1", "mobilenet_v2", "inception_v3", "resnet50"];

/// §3 compression subjects.
pub const COMPRESS_MODELS: [&str; 4] = ["lenet5", "alexnet", "vgg16", "resnet18"];

/// Build any model by name at the given batch size.
pub fn build(name: &str, batch: usize) -> Option<Graph> {
    Some(match name {
        "lenet5" => classic::lenet5(batch),
        "alexnet" => classic::alexnet(batch),
        "vgg16" => classic::vgg16(batch),
        "resnet18" => resnet::resnet18(batch),
        "resnet50" => resnet::resnet50(batch),
        "mobilenet_v1" => mobilenet::v1(batch),
        "mobilenet_v2" => mobilenet::v2(batch),
        "inception_v3" => inception::v3(batch),
        _ => return None,
    })
}

pub fn all_names() -> Vec<&'static str> {
    vec![
        "lenet5", "alexnet", "vgg16", "resnet18", "resnet50",
        "mobilenet_v1", "mobilenet_v2", "inception_v3",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        for name in all_names() {
            let g = build(name, 1).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.flops() > 0, "{name} has zero flops");
        }
    }

    #[test]
    fn batch_scales_flops_linearly() {
        let f1 = build("resnet50", 1).unwrap().flops();
        let f4 = build("resnet50", 4).unwrap().flops();
        assert_eq!(f4, 4 * f1);
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(build("nope", 1).is_none());
    }

    /// Table 2 "Size (M)" pins: params * 4 bytes within 2% of the paper.
    #[test]
    fn table2_model_sizes() {
        let cases = [
            ("mobilenet_v1", 17.1),
            ("mobilenet_v2", 14.1),
            ("inception_v3", 95.4),
            ("resnet50", 102.4),
        ];
        for (name, paper_mb) in cases {
            let g = build(name, 1).unwrap();
            let mb = g.size_mb();
            let rel = (mb - paper_mb).abs() / paper_mb;
            assert!(rel < 0.02, "{name}: {mb:.1} MB vs paper {paper_mb} MB ({rel:.3})");
        }
    }

    /// Canonical parameter counts for the §3 subjects.
    #[test]
    fn classic_param_counts() {
        assert_eq!(build("lenet5", 1).unwrap().param_count(), 61_706);
        assert_eq!(build("alexnet", 1).unwrap().param_count(), 60_965_224);
        assert_eq!(build("vgg16", 1).unwrap().param_count(), 138_357_544);
        // ResNet-18: 11.69M (weights + BN), canonical torchvision count.
        let r18 = build("resnet18", 1).unwrap().param_count();
        assert!((11_600_000..11_800_000).contains(&r18), "resnet18: {r18}");
    }

    /// ResNet-50: 25.557M *learnable* params (torchvision convention:
    /// BN gamma/beta only) — our stored-model convention also counts BN
    /// running stats (4/channel, what a deployed file ships), giving
    /// 25.610M = 102.4 MB, exactly Table 2's "102.4".
    #[test]
    fn resnet50_param_count() {
        let g = build("resnet50", 1).unwrap();
        assert_eq!(g.param_count(), 25_610_152, "stored params (BN=4/c)");
        // learnable convention: subtract the 2 running stats per BN channel
        let bn_channels: usize = g
            .nodes
            .iter()
            .map(|n| match n.op {
                crate::ir::Op::BatchNorm { c } => c,
                _ => 0,
            })
            .sum();
        assert_eq!(g.param_count() - 2 * bn_channels, 25_557_032, "learnable params");
    }
}
