//! Pre-BN classics: LeNet-5, AlexNet (grouped, 2-tower), VGG-16.

use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, NodeId, Shape};

fn relu(g: &mut Graph, name: &str, x: NodeId) -> NodeId {
    g.add(format!("{name}_relu"), Op::Activation { kind: ActKind::Relu }, vec![x])
}

fn maxpool(g: &mut Graph, name: &str, x: NodeId, k: usize, s: usize) -> NodeId {
    g.add(name, Op::Pool { kind: PoolKind::Max, k, stride: s, padding: 0 }, vec![x])
}

/// LeNet-5 (28x28x1, 'same' c1 then 'valid' c2 — the common MNIST
/// variant; 61,706 params).
pub fn lenet5(batch: usize) -> Graph {
    let mut g = Graph::new("lenet5", Shape::nhwc(batch, 28, 28, 1));
    let mut x = g.add("c1", Op::conv_b(5, 5, 1, 6, 1, 2), vec![0]);
    x = relu(&mut g, "c1", x);
    x = maxpool(&mut g, "p1", x, 2, 2);
    x = g.add("c2", Op::conv_b(5, 5, 6, 16, 1, 0), vec![x]);
    x = relu(&mut g, "c2", x);
    x = maxpool(&mut g, "p2", x, 2, 2);
    x = g.add("flat", Op::Flatten, vec![x]);
    x = g.add("f1", Op::fc(400, 120), vec![x]);
    x = relu(&mut g, "f1", x);
    x = g.add("f2", Op::fc(120, 84), vec![x]);
    x = relu(&mut g, "f2", x);
    x = g.add("f3", Op::fc(84, 10), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

/// AlexNet (original grouped variant; 60,965,224 params at 1000 classes).
pub fn alexnet(batch: usize) -> Graph {
    let mut g = Graph::new("alexnet", Shape::nhwc(batch, 227, 227, 3));
    let mut x = g.add("conv1", Op::conv_b(11, 11, 3, 96, 4, 0), vec![0]);
    x = relu(&mut g, "conv1", x);
    x = maxpool(&mut g, "pool1", x, 3, 2);
    x = g.add("conv2", Op::conv_bg(5, 5, 96, 256, 1, 2, 2), vec![x]);
    x = relu(&mut g, "conv2", x);
    x = maxpool(&mut g, "pool2", x, 3, 2);
    x = g.add("conv3", Op::conv_b(3, 3, 256, 384, 1, 1), vec![x]);
    x = relu(&mut g, "conv3", x);
    x = g.add("conv4", Op::conv_bg(3, 3, 384, 384, 1, 1, 2), vec![x]);
    x = relu(&mut g, "conv4", x);
    x = g.add("conv5", Op::conv_bg(3, 3, 384, 256, 1, 1, 2), vec![x]);
    x = relu(&mut g, "conv5", x);
    x = maxpool(&mut g, "pool5", x, 3, 2);
    x = g.add("flat", Op::Flatten, vec![x]);
    x = g.add("fc6", Op::fc(9216, 4096), vec![x]);
    x = relu(&mut g, "fc6", x);
    x = g.add("fc7", Op::fc(4096, 4096), vec![x]);
    x = relu(&mut g, "fc7", x);
    x = g.add("fc8", Op::fc(4096, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

/// VGG-16 (configuration D; 138,357,544 params at 1000 classes).
pub fn vgg16(batch: usize) -> Graph {
    let mut g = Graph::new("vgg16", Shape::nhwc(batch, 224, 224, 3));
    let mut x: NodeId = 0;
    let cfg: [(usize, usize, usize); 13] = [
        (1, 3, 64), (2, 64, 64),
        (1, 64, 128), (2, 128, 128),
        (1, 128, 256), (2, 256, 256), (3, 256, 256),
        (1, 256, 512), (2, 512, 512), (3, 512, 512),
        (1, 512, 512), (2, 512, 512), (3, 512, 512),
    ];
    let mut stage = 1usize;
    for (i, (idx, cin, cout)) in cfg.iter().enumerate() {
        let name = format!("conv{stage}_{idx}");
        x = g.add(&name, Op::conv_b(3, 3, *cin, *cout, 1, 1), vec![x]);
        x = relu(&mut g, &name, x);
        // pool after the last conv of each stage (indices 1,3,6,9,12)
        if matches!(i, 1 | 3 | 6 | 9 | 12) {
            x = maxpool(&mut g, &format!("pool{stage}"), x, 2, 2);
            stage += 1;
        }
    }
    x = g.add("flat", Op::Flatten, vec![x]);
    x = g.add("fc6", Op::fc(25088, 4096), vec![x]);
    x = relu(&mut g, "fc6", x);
    x = g.add("fc7", Op::fc(4096, 4096), vec![x]);
    x = relu(&mut g, "fc7", x);
    x = g.add("fc8", Op::fc(4096, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet5_shapes() {
        let g = lenet5(2);
        assert!(g.validate().is_ok());
        assert_eq!(g.nodes.last().unwrap().shape, Shape::vec2(2, 10));
        assert_eq!(g.param_count(), 61_706);
        assert_eq!(g.weight_layer_count(), 5);
    }

    #[test]
    fn alexnet_fc6_geometry() {
        // pool5 must produce 6x6x256 = 9216 features
        let g = alexnet(1);
        let flat = g.nodes.iter().find(|n| n.name == "flat").unwrap();
        assert_eq!(flat.shape, Shape::vec2(1, 9216));
        assert_eq!(g.param_count(), 60_965_224);
    }

    #[test]
    fn alexnet_grouped_conv2_weights() {
        let g = alexnet(1);
        let c2 = g.nodes.iter().find(|n| n.name == "conv2").unwrap();
        assert_eq!(c2.op.weight_count(), 307_200); // 5*5*48*256
    }

    #[test]
    fn vgg16_geometry_and_params() {
        let g = vgg16(1);
        assert!(g.validate().is_ok());
        let flat = g.nodes.iter().find(|n| n.name == "flat").unwrap();
        assert_eq!(flat.shape, Shape::vec2(1, 25088)); // 7*7*512
        assert_eq!(g.param_count(), 138_357_544);
        assert_eq!(g.weight_layer_count(), 16);
    }

    #[test]
    fn vgg16_conv_weight_profile_matches_compress_run() {
        // The python compress_run.py profile hard-codes these; keep in sync.
        let g = vgg16(1);
        let w = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).unwrap().op.weight_count()
        };
        assert_eq!(w("conv1_1"), 1_728);
        assert_eq!(w("conv3_2"), 589_824);
        assert_eq!(w("conv5_3"), 2_359_296);
        assert_eq!(w("fc6"), 102_760_448);
    }
}
