//! ResNet-18 (basic blocks) and ResNet-50 (bottleneck blocks), ImageNet
//! geometry, BN after every conv (pre-pass graphs: Conv/BN/Act separate).

use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, NodeId, Shape};

fn conv_bn(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    kh: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padding: usize,
    relu: bool,
) -> NodeId {
    let c = g.add(name, Op::conv(kh, kh, cin, cout, stride, padding), vec![x]);
    let b = g.add(format!("{name}_bn"), Op::BatchNorm { c: cout }, vec![c]);
    if relu {
        g.add(format!("{name}_relu"), Op::Activation { kind: ActKind::Relu }, vec![b])
    } else {
        b
    }
}

fn stem(g: &mut Graph) -> NodeId {
    let x = conv_bn(g, "conv1", 0, 7, 3, 64, 2, 3, true);
    g.add("maxpool", Op::Pool { kind: PoolKind::Max, k: 3, stride: 2, padding: 1 }, vec![x])
}

/// Basic block: 3x3 -> 3x3 (+ 1x1 downsample shortcut when needed).
fn basic_block(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
) -> NodeId {
    let c1 = conv_bn(g, &format!("{name}_c1"), x, 3, cin, cout, stride, 1, true);
    let c2 = conv_bn(g, &format!("{name}_c2"), c1, 3, cout, cout, 1, 1, false);
    let shortcut = if stride != 1 || cin != cout {
        conv_bn(g, &format!("{name}_down"), x, 1, cin, cout, stride, 0, false)
    } else {
        x
    };
    let add = g.add(format!("{name}_add"), Op::Add, vec![c2, shortcut]);
    g.add(format!("{name}_out"), Op::Activation { kind: ActKind::Relu }, vec![add])
}

/// Bottleneck block: 1x1 reduce -> 3x3 -> 1x1 expand (expansion 4).
fn bottleneck(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    cin: usize,
    planes: usize,
    stride: usize,
) -> NodeId {
    let cout = planes * 4;
    let c1 = conv_bn(g, &format!("{name}_c1"), x, 1, cin, planes, 1, 0, true);
    let c2 = conv_bn(g, &format!("{name}_c2"), c1, 3, planes, planes, stride, 1, true);
    let c3 = conv_bn(g, &format!("{name}_c3"), c2, 1, planes, cout, 1, 0, false);
    let shortcut = if stride != 1 || cin != cout {
        conv_bn(g, &format!("{name}_down"), x, 1, cin, cout, stride, 0, false)
    } else {
        x
    };
    let add = g.add(format!("{name}_add"), Op::Add, vec![c3, shortcut]);
    g.add(format!("{name}_out"), Op::Activation { kind: ActKind::Relu }, vec![add])
}

pub fn resnet18(batch: usize) -> Graph {
    let mut g = Graph::new("resnet18", Shape::nhwc(batch, 224, 224, 3));
    let mut x = stem(&mut g);
    let mut cin = 64;
    for (si, (planes, blocks)) in [(64, 2), (128, 2), (256, 2), (512, 2)].iter().enumerate() {
        for b in 0..*blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            x = basic_block(&mut g, &format!("s{si}b{b}"), x, cin, *planes, stride);
            cin = *planes;
        }
    }
    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    x = g.add("fc", Op::fc(512, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

pub fn resnet50(batch: usize) -> Graph {
    let mut g = Graph::new("resnet50", Shape::nhwc(batch, 224, 224, 3));
    let mut x = stem(&mut g);
    let mut cin = 64;
    for (si, (planes, blocks)) in [(64, 3), (128, 4), (256, 6), (512, 3)].iter().enumerate() {
        for b in 0..*blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            x = bottleneck(&mut g, &format!("s{si}b{b}"), x, cin, *planes, stride);
            cin = planes * 4;
        }
    }
    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    x = g.add("fc", Op::fc(2048, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_structure() {
        let g = resnet50(1);
        assert!(g.validate().is_ok());
        // 53 convs + 1 fc
        assert_eq!(g.weight_layer_count(), 54);
        assert_eq!(g.nodes.last().unwrap().shape, Shape::vec2(1, 1000));
        // ~4.1 GFLOPs/image (2 * 2.05 GMACs, includes BN/act/pool overhead)
        let gf = g.flops() as f64 / 1e9;
        assert!((7.5..8.6).contains(&gf), "resnet50 flops {gf}");
    }

    #[test]
    fn resnet18_structure() {
        let g = resnet18(1);
        assert!(g.validate().is_ok());
        assert_eq!(g.weight_layer_count(), 21); // 20 convs + fc
        let gf = g.flops() as f64 / 1e9;
        assert!((3.3..3.9).contains(&gf), "resnet18 flops {gf}");
    }

    #[test]
    fn stage_downsampling_shapes() {
        let g = resnet50(1);
        let find = |n: &str| g.nodes.iter().find(|x| x.name == n).unwrap().shape.clone();
        assert_eq!(find("maxpool"), Shape::nhwc(1, 56, 56, 64));
        assert_eq!(find("s0b2_out"), Shape::nhwc(1, 56, 56, 256));
        assert_eq!(find("s1b0_out"), Shape::nhwc(1, 28, 28, 512));
        assert_eq!(find("s3b2_out"), Shape::nhwc(1, 7, 7, 2048));
    }
}
