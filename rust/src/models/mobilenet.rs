//! MobileNet-V1 (depthwise-separable) and MobileNet-V2 (inverted
//! residuals, ReLU6), width multiplier 1.0, 224x224.

use crate::ir::ops::{ActKind, Op};
use crate::ir::{Graph, NodeId, Shape};

fn conv_bn_act(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    kh: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padding: usize,
    act: ActKind,
) -> NodeId {
    let c = g.add(name, Op::conv(kh, kh, cin, cout, stride, padding), vec![x]);
    let b = g.add(format!("{name}_bn"), Op::BatchNorm { c: cout }, vec![c]);
    if act == ActKind::None {
        b
    } else {
        g.add(format!("{name}_act"), Op::Activation { kind: act }, vec![b])
    }
}

fn dw_bn_act(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    c: usize,
    stride: usize,
    act: ActKind,
) -> NodeId {
    let d = g.add(name, Op::DepthwiseConv2d { kh: 3, kw: 3, c, stride, padding: 1 }, vec![x]);
    let b = g.add(format!("{name}_bn"), Op::BatchNorm { c }, vec![d]);
    g.add(format!("{name}_act"), Op::Activation { kind: act }, vec![b])
}

/// MobileNet-V1: stem + 13 depthwise-separable blocks (paper §4's
/// "Depthwise Conv + BN + Activation" fusion target).
pub fn v1(batch: usize) -> Graph {
    let mut g = Graph::new("mobilenet_v1", Shape::nhwc(batch, 224, 224, 3));
    let mut x = conv_bn_act(&mut g, "stem", 0, 3, 3, 32, 2, 1, ActKind::Relu);
    // (cin, cout, stride) for the 13 separable blocks
    let blocks: [(usize, usize, usize); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for (i, (cin, cout, s)) in blocks.iter().enumerate() {
        x = dw_bn_act(&mut g, &format!("b{i}_dw"), x, *cin, *s, ActKind::Relu);
        x = conv_bn_act(&mut g, &format!("b{i}_pw"), x, 1, *cin, *cout, 1, 0, ActKind::Relu);
    }
    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    x = g.add("fc", Op::fc(1024, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

/// One MobileNet-V2 inverted-residual block.
fn inverted_residual(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
    expand: usize,
) -> NodeId {
    let hidden = cin * expand;
    let mut h = x;
    if expand != 1 {
        h = conv_bn_act(g, &format!("{name}_exp"), h, 1, cin, hidden, 1, 0, ActKind::Relu6);
    }
    h = dw_bn_act(g, &format!("{name}_dw"), h, hidden, stride, ActKind::Relu6);
    // linear bottleneck: no activation after the projection
    h = conv_bn_act(g, &format!("{name}_proj"), h, 1, hidden, cout, 1, 0, ActKind::None);
    if stride == 1 && cin == cout {
        g.add(format!("{name}_add"), Op::Add, vec![h, x])
    } else {
        h
    }
}

/// MobileNet-V2 (t,c,n,s table from the paper).
pub fn v2(batch: usize) -> Graph {
    let mut g = Graph::new("mobilenet_v2", Shape::nhwc(batch, 224, 224, 3));
    let mut x = conv_bn_act(&mut g, "stem", 0, 3, 3, 32, 2, 1, ActKind::Relu6);
    let cfg: [(usize, usize, usize, usize); 7] = [
        // (expand, cout, repeats, stride)
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    for (bi, (t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..*n {
            let stride = if r == 0 { *s } else { 1 };
            x = inverted_residual(&mut g, &format!("ir{bi}_{r}"), x, cin, *c, stride, *t);
            cin = *c;
        }
    }
    x = conv_bn_act(&mut g, "head", x, 1, 320, 1280, 1, 0, ActKind::Relu6);
    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    x = g.add("fc", Op::fc(1280, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_params_match_table2() {
        let g = v1(1);
        assert!(g.validate().is_ok());
        // canonical 4.23M params -> 16.9 MB; Table 2 says 17.1 MB
        let p = g.param_count();
        assert!((4_200_000..4_280_000).contains(&p), "v1 params {p}");
        // 27 convs (1 stem + 13 dw + 13 pw) + 1 fc
        assert_eq!(g.weight_layer_count(), 28);
    }

    #[test]
    fn v1_flops_around_1_1g() {
        let gf = v1(1).flops() as f64 / 1e9;
        assert!((1.1..1.3).contains(&gf), "v1 flops {gf}");
    }

    #[test]
    fn v2_params_match_table2() {
        let g = v2(1);
        assert!(g.validate().is_ok());
        let p = g.param_count();
        assert!((3_470_000..3_540_000).contains(&p), "v2 params {p}");
    }

    #[test]
    fn v2_residual_adds_present() {
        let g = v2(1);
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
        // repeats beyond the first in each stage: 1+2+3+2+2+0 = 10
        assert_eq!(adds, 10);
    }

    #[test]
    fn v2_final_spatial_7x7() {
        let g = v2(1);
        let head = g.nodes.iter().find(|n| n.name == "head_act").unwrap();
        assert_eq!(head.shape, Shape::nhwc(1, 7, 7, 1280));
    }
}
