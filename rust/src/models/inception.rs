//! Inception-V3 (299x299, no aux head at inference) — the torchvision /
//! TF-slim structure: stem, 3x InceptionA, InceptionB, 4x InceptionC,
//! InceptionD, 2x InceptionE, GAP, FC.

use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, NodeId, Shape};

/// BasicConv2d: conv (possibly asymmetric) + BN + ReLU.
#[allow(clippy::too_many_arguments)]
fn bconv(
    g: &mut Graph,
    name: &str,
    x: NodeId,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padh: usize,
    padw: usize,
) -> NodeId {
    let c = g.add(name, Op::conv_asym(kh, kw, cin, cout, stride, padh, padw), vec![x]);
    let b = g.add(format!("{name}_bn"), Op::BatchNorm { c: cout }, vec![c]);
    g.add(format!("{name}_relu"), Op::Activation { kind: ActKind::Relu }, vec![b])
}

fn avgpool3(g: &mut Graph, name: &str, x: NodeId) -> NodeId {
    g.add(name, Op::Pool { kind: PoolKind::Avg, k: 3, stride: 1, padding: 1 }, vec![x])
}

fn maxpool3s2(g: &mut Graph, name: &str, x: NodeId) -> NodeId {
    g.add(name, Op::Pool { kind: PoolKind::Max, k: 3, stride: 2, padding: 0 }, vec![x])
}

/// InceptionA(cin, pool_features): out = 64 + 64 + 96 + pf channels.
fn inception_a(g: &mut Graph, name: &str, x: NodeId, cin: usize, pf: usize) -> NodeId {
    let b1 = bconv(g, &format!("{name}_1x1"), x, 1, 1, cin, 64, 1, 0, 0);
    let b5 = bconv(g, &format!("{name}_5x5a"), x, 1, 1, cin, 48, 1, 0, 0);
    let b5 = bconv(g, &format!("{name}_5x5b"), b5, 5, 5, 48, 64, 1, 2, 2);
    let d = bconv(g, &format!("{name}_dbl_a"), x, 1, 1, cin, 64, 1, 0, 0);
    let d = bconv(g, &format!("{name}_dbl_b"), d, 3, 3, 64, 96, 1, 1, 1);
    let d = bconv(g, &format!("{name}_dbl_c"), d, 3, 3, 96, 96, 1, 1, 1);
    let p = avgpool3(g, &format!("{name}_pool"), x);
    let p = bconv(g, &format!("{name}_pool_proj"), p, 1, 1, cin, pf, 1, 0, 0);
    g.add(format!("{name}_cat"), Op::Concat, vec![b1, b5, d, p])
}

/// InceptionB (grid reduction 35 -> 17): out = 384 + 96 + cin.
fn inception_b(g: &mut Graph, name: &str, x: NodeId, cin: usize) -> NodeId {
    let b3 = bconv(g, &format!("{name}_3x3"), x, 3, 3, cin, 384, 2, 0, 0);
    let d = bconv(g, &format!("{name}_dbl_a"), x, 1, 1, cin, 64, 1, 0, 0);
    let d = bconv(g, &format!("{name}_dbl_b"), d, 3, 3, 64, 96, 1, 1, 1);
    let d = bconv(g, &format!("{name}_dbl_c"), d, 3, 3, 96, 96, 2, 0, 0);
    let p = maxpool3s2(g, &format!("{name}_pool"), x);
    g.add(format!("{name}_cat"), Op::Concat, vec![b3, d, p])
}

/// InceptionC (17x17, factorized 7x7; c7 = intermediate width): out = 768.
fn inception_c(g: &mut Graph, name: &str, x: NodeId, cin: usize, c7: usize) -> NodeId {
    let b1 = bconv(g, &format!("{name}_1x1"), x, 1, 1, cin, 192, 1, 0, 0);
    let b7 = bconv(g, &format!("{name}_7a"), x, 1, 1, cin, c7, 1, 0, 0);
    let b7 = bconv(g, &format!("{name}_7b"), b7, 1, 7, c7, c7, 1, 0, 3);
    let b7 = bconv(g, &format!("{name}_7c"), b7, 7, 1, c7, 192, 1, 3, 0);
    let d = bconv(g, &format!("{name}_7dbl_a"), x, 1, 1, cin, c7, 1, 0, 0);
    let d = bconv(g, &format!("{name}_7dbl_b"), d, 7, 1, c7, c7, 1, 3, 0);
    let d = bconv(g, &format!("{name}_7dbl_c"), d, 1, 7, c7, c7, 1, 0, 3);
    let d = bconv(g, &format!("{name}_7dbl_d"), d, 7, 1, c7, c7, 1, 3, 0);
    let d = bconv(g, &format!("{name}_7dbl_e"), d, 1, 7, c7, 192, 1, 0, 3);
    let p = avgpool3(g, &format!("{name}_pool"), x);
    let p = bconv(g, &format!("{name}_pool_proj"), p, 1, 1, cin, 192, 1, 0, 0);
    g.add(format!("{name}_cat"), Op::Concat, vec![b1, b7, d, p])
}

/// InceptionD (grid reduction 17 -> 8): out = 320 + 192 + cin.
fn inception_d(g: &mut Graph, name: &str, x: NodeId, cin: usize) -> NodeId {
    let b3 = bconv(g, &format!("{name}_3x3a"), x, 1, 1, cin, 192, 1, 0, 0);
    let b3 = bconv(g, &format!("{name}_3x3b"), b3, 3, 3, 192, 320, 2, 0, 0);
    let b7 = bconv(g, &format!("{name}_7x7a"), x, 1, 1, cin, 192, 1, 0, 0);
    let b7 = bconv(g, &format!("{name}_7x7b"), b7, 1, 7, 192, 192, 1, 0, 3);
    let b7 = bconv(g, &format!("{name}_7x7c"), b7, 7, 1, 192, 192, 1, 3, 0);
    let b7 = bconv(g, &format!("{name}_7x7d"), b7, 3, 3, 192, 192, 2, 0, 0);
    let p = maxpool3s2(g, &format!("{name}_pool"), x);
    g.add(format!("{name}_cat"), Op::Concat, vec![b3, b7, p])
}

/// InceptionE (8x8, expanded splits): out = 320 + 768 + 768 + 192 = 2048.
fn inception_e(g: &mut Graph, name: &str, x: NodeId, cin: usize) -> NodeId {
    let b1 = bconv(g, &format!("{name}_1x1"), x, 1, 1, cin, 320, 1, 0, 0);
    let b3 = bconv(g, &format!("{name}_3x3a"), x, 1, 1, cin, 384, 1, 0, 0);
    let b3a = bconv(g, &format!("{name}_3x3b1"), b3, 1, 3, 384, 384, 1, 0, 1);
    let b3b = bconv(g, &format!("{name}_3x3b2"), b3, 3, 1, 384, 384, 1, 1, 0);
    let b3 = g.add(format!("{name}_3x3cat"), Op::Concat, vec![b3a, b3b]);
    let d = bconv(g, &format!("{name}_dbl_a"), x, 1, 1, cin, 448, 1, 0, 0);
    let d = bconv(g, &format!("{name}_dbl_b"), d, 3, 3, 448, 384, 1, 1, 1);
    let da = bconv(g, &format!("{name}_dbl_c1"), d, 1, 3, 384, 384, 1, 0, 1);
    let db = bconv(g, &format!("{name}_dbl_c2"), d, 3, 1, 384, 384, 1, 1, 0);
    let d = g.add(format!("{name}_dblcat"), Op::Concat, vec![da, db]);
    let p = avgpool3(g, &format!("{name}_pool"), x);
    let p = bconv(g, &format!("{name}_pool_proj"), p, 1, 1, cin, 192, 1, 0, 0);
    g.add(format!("{name}_cat"), Op::Concat, vec![b1, b3, d, p])
}

pub fn v3(batch: usize) -> Graph {
    let mut g = Graph::new("inception_v3", Shape::nhwc(batch, 299, 299, 3));
    // stem
    let mut x = bconv(&mut g, "stem1", 0, 3, 3, 3, 32, 2, 0, 0); // 149
    x = bconv(&mut g, "stem2", x, 3, 3, 32, 32, 1, 0, 0); // 147
    x = bconv(&mut g, "stem3", x, 3, 3, 32, 64, 1, 1, 1); // 147
    x = maxpool3s2(&mut g, "stem_pool1", x); // 73
    x = bconv(&mut g, "stem4", x, 1, 1, 64, 80, 1, 0, 0);
    x = bconv(&mut g, "stem5", x, 3, 3, 80, 192, 1, 0, 0); // 71
    x = maxpool3s2(&mut g, "stem_pool2", x); // 35
    // 3x InceptionA
    x = inception_a(&mut g, "mixed0", x, 192, 32); // 256
    x = inception_a(&mut g, "mixed1", x, 256, 64); // 288
    x = inception_a(&mut g, "mixed2", x, 288, 64); // 288
    // reduction
    x = inception_b(&mut g, "mixed3", x, 288); // 768 @ 17
    // 4x InceptionC
    x = inception_c(&mut g, "mixed4", x, 768, 128);
    x = inception_c(&mut g, "mixed5", x, 768, 160);
    x = inception_c(&mut g, "mixed6", x, 768, 160);
    x = inception_c(&mut g, "mixed7", x, 768, 192);
    // reduction
    x = inception_d(&mut g, "mixed8", x, 768); // 1280 @ 8
    // 2x InceptionE
    x = inception_e(&mut g, "mixed9", x, 1280); // 2048
    x = inception_e(&mut g, "mixed10", x, 2048); // 2048
    x = g.add("gap", Op::GlobalAvgPool, vec![x]);
    x = g.add("fc", Op::fc(2048, 1000), vec![x]);
    g.add("softmax", Op::Softmax, vec![x]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let g = v3(1);
        g.validate().unwrap();
        assert_eq!(g.nodes.last().unwrap().shape, Shape::vec2(1, 1000));
    }

    #[test]
    fn params_match_table2() {
        // canonical 23.85M params -> 95.4 MB (Table 2: 95.4)
        let g = v3(1);
        let p = g.param_count();
        assert!(
            (23_600_000..24_000_000).contains(&p),
            "inception_v3 params {p}"
        );
    }

    #[test]
    fn grid_sizes() {
        let g = v3(1);
        let find = |n: &str| g.nodes.iter().find(|x| x.name == n).unwrap().shape.clone();
        assert_eq!(find("mixed2_cat"), Shape::nhwc(1, 35, 35, 288));
        assert_eq!(find("mixed3_cat"), Shape::nhwc(1, 17, 17, 768));
        assert_eq!(find("mixed8_cat"), Shape::nhwc(1, 8, 8, 1280));
        assert_eq!(find("mixed10_cat"), Shape::nhwc(1, 8, 8, 2048));
    }

    #[test]
    fn flops_around_6g() {
        // canonical ~5.7 GMACs -> ~11.4 GFLOPs (2*MACs convention)
        let gf = v3(1).flops() as f64 / 1e9;
        assert!((10.5..12.5).contains(&gf), "inception flops {gf}");
    }
}
