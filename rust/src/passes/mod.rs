//! Architecture-aware compiler passes over the IR (paper §4).
//!
//! - `fusion`       — Conv/DwConv + BatchNorm + Activation -> one fused op
//! - `conv1x1_gemm` — 1x1 convolutions -> GEMM
//! - `layout`       — tiling / alignment / padding planning
//! - `load_elim`    — redundant-register-load elimination analysis
//!
//! Passes are pure Graph -> Graph rewrites; a rebuild helper keeps ids
//! dense and topological. The framework personalities in `exec/` differ
//! exactly in which passes they run — that is how the Figure 2 baselines
//! (TFLite-like: none; TVM-like: fusion+gemm; CADNN: all) are expressed.

pub mod conv1x1_gemm;
pub mod fusion;
pub mod layout;
pub mod load_elim;

use crate::ir::Graph;

/// A named graph rewrite.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, g: &Graph) -> Graph;
}

/// Run a pipeline of passes in order.
pub fn run_pipeline(g: &Graph, passes: &[&dyn Pass]) -> Graph {
    let mut out = g.clone();
    for p in passes {
        out = p.run(&out);
        debug_assert!(out.validate().is_ok(), "pass {} broke the graph", p.name());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn full_pipeline_on_all_models() {
        let fusion = fusion::FusionPass;
        let gemm = conv1x1_gemm::Conv1x1ToGemm;
        for name in models::all_names() {
            let g = models::build(name, 1).unwrap();
            let out = run_pipeline(&g, &[&fusion, &gemm]);
            out.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            // passes must preserve the final logits shape
            assert_eq!(
                g.nodes.last().unwrap().shape,
                out.nodes.last().unwrap().shape,
                "{name} output shape changed"
            );
        }
    }
}
