//! 1x1-convolution -> GEMM transformation (paper §4).
//!
//! A stride-1, pad-0 1x1 conv over NHWC is *exactly* a
//! (N*H*W, Cin) x (Cin, Cout) matrix multiply on the same buffer (NHWC
//! row-major flattens to rows of Cin features). The rewrite keeps the
//! NHWC output shape in the Gemm op so downstream shape inference is
//! untouched; the executor treats the buffer as 2-D.

use super::Pass;
use crate::ir::ops::{ActKind, Op};
use crate::ir::Graph;

pub struct Conv1x1ToGemm;

impl Pass for Conv1x1ToGemm {
    fn name(&self) -> &'static str {
        "conv1x1_to_gemm"
    }

    fn run(&self, g: &Graph) -> Graph {
        let mut out = Graph::new(&g.name, g.nodes[0].shape.clone());
        for n in g.nodes.iter().skip(1) {
            let in_shape = &g.node(n.inputs[0]).shape;
            let new_op = match &n.op {
                // fused 1x1 conv (post-fusion pipelines)
                Op::FusedConvBnAct {
                    kh: 1, kw: 1, cin, cout, stride: 1, padh: 0, padw: 0, act, groups: 1,
                } => Some(Op::Gemm {
                    m: in_shape.n() * in_shape.h() * in_shape.w(),
                    k: *cin,
                    n: *cout,
                    act: *act,
                    fused_epilogue: true,
                    out_shape: n.shape.clone(),
                }),
                // bare 1x1 conv (unfused pipelines keep bn/act separate)
                Op::Conv2d {
                    kh: 1, kw: 1, cin, cout, stride: 1, padh: 0, padw: 0, bias, groups: 1,
                } => Some(Op::Gemm {
                    m: in_shape.n() * in_shape.h() * in_shape.w(),
                    k: *cin,
                    n: *cout,
                    act: ActKind::None,
                    fused_epilogue: *bias,
                    out_shape: n.shape.clone(),
                }),
                _ => None,
            };
            out.add(n.name.clone(), new_op.unwrap_or_else(|| n.op.clone()), n.inputs.clone());
        }
        out.output = g.output;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::fusion::FusionPass;
    use crate::models;

    fn count_kind(g: &Graph, name: &str) -> usize {
        g.nodes.iter().filter(|n| n.op.name() == name).count()
    }

    #[test]
    fn mobilenet_v1_pointwise_become_gemm() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let f = FusionPass.run(&g);
        let t = Conv1x1ToGemm.run(&f);
        t.validate().unwrap();
        assert_eq!(count_kind(&t, "gemm"), 13); // all pointwise convs
        assert_eq!(count_kind(&t, "fused_conv_bn_act"), 1); // 3x3 stem stays
    }

    #[test]
    fn resnet50_bottleneck_1x1s_transform() {
        let g = models::build("resnet50", 1).unwrap();
        let t = Conv1x1ToGemm.run(&FusionPass.run(&g));
        t.validate().unwrap();
        // 1x1 convs: c1+c3 per block (32) + stride-1 downsample (only s0:
        // stride-2 downsamples are NOT gemm-eligible) = 33
        assert_eq!(count_kind(&t, "gemm"), 33);
    }

    #[test]
    fn gemm_preserves_flops() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let f = FusionPass.run(&g);
        let t = Conv1x1ToGemm.run(&f);
        let (a, b) = (f.flops() as f64, t.flops() as f64);
        assert!((a - b).abs() / a < 0.02, "{a} vs {b}");
    }

    #[test]
    fn strided_1x1_not_transformed() {
        // build a graph with a stride-2 1x1 conv: must stay a conv
        use crate::ir::Shape;
        let mut g = Graph::new("t", Shape::nhwc(1, 8, 8, 4));
        g.add("c", Op::conv(1, 1, 4, 8, 2, 0), vec![0]);
        let t = Conv1x1ToGemm.run(&g);
        assert_eq!(count_kind(&t, "conv2d"), 1);
        assert_eq!(count_kind(&t, "gemm"), 0);
    }
}
