//! Fusion pass: Conv2d/DepthwiseConv2d + BatchNorm + Activation chains
//! become single fused kernels (paper §4 "model computation fusion").
//!
//! Matching is consumer-aware: a BN or Act node is absorbed only when it
//! is the *sole* consumer of its producer, so residual taps (e.g. ResNet
//! shortcuts read the pre-activation tensor) are never miscompiled.

use super::Pass;
use crate::ir::ops::{ActKind, Op};
use crate::ir::{Graph, NodeId};

pub struct FusionPass;

impl Pass for FusionPass {
    fn name(&self) -> &'static str {
        "fusion"
    }

    fn run(&self, g: &Graph) -> Graph {
        let consumers = g.consumers();
        // Nodes absorbed into a predecessor; maps old id -> old id whose
        // rewritten node produces its value.
        let mut absorbed: Vec<Option<NodeId>> = vec![None; g.len()];
        // Fused op replacement for conv nodes (old conv id -> fused op +
        // the last absorbed old id, whose consumers move to the fusion).
        let mut fused: Vec<Option<(Op, NodeId)>> = vec![None; g.len()];

        for n in &g.nodes {
            let (conv_like, is_dw) = match &n.op {
                Op::Conv2d { bias: false, .. } => (true, false),
                Op::DepthwiseConv2d { .. } => (true, true),
                _ => (false, false),
            };
            if !conv_like {
                continue;
            }
            // conv -> bn (sole consumer)
            let bn_id = match consumers[n.id].as_slice() {
                [b] if matches!(g.node(*b).op, Op::BatchNorm { .. }) => *b,
                _ => continue,
            };
            // bn -> act (sole consumer) — optional
            let (act, tail) = match consumers[bn_id].as_slice() {
                [a] => match g.node(*a).op {
                    Op::Activation { kind } => (kind, *a),
                    _ => (ActKind::None, bn_id),
                },
                _ => (ActKind::None, bn_id),
            };
            let fused_op = match &n.op {
                Op::Conv2d { kh, kw, cin, cout, stride, padh, padw, groups, .. } => {
                    Op::FusedConvBnAct {
                        kh: *kh, kw: *kw, cin: *cin, cout: *cout,
                        stride: *stride, padh: *padh, padw: *padw,
                        act, groups: *groups,
                    }
                }
                Op::DepthwiseConv2d { kh, kw, c, stride, padding } => {
                    debug_assert!(is_dw);
                    Op::FusedDwBnAct {
                        kh: *kh, kw: *kw, c: *c,
                        stride: *stride, padding: *padding, act,
                    }
                }
                _ => unreachable!(),
            };
            fused[n.id] = Some((fused_op, tail));
            absorbed[bn_id] = Some(n.id);
            if tail != bn_id {
                absorbed[tail] = Some(n.id);
            }
        }

        // Rebuild with dense ids.
        let input_shape = g.nodes[0].shape.clone();
        let mut out = Graph::new(&g.name, input_shape);
        // old id -> new id (for nodes that exist in the new graph; absorbed
        // nodes map to their fusion's new id).
        let mut remap: Vec<Option<NodeId>> = vec![None; g.len()];
        remap[0] = Some(0);
        for n in g.nodes.iter().skip(1) {
            if absorbed[n.id].is_some() {
                continue; // value produced by the fused node
            }
            let inputs: Vec<NodeId> = n
                .inputs
                .iter()
                .map(|&i| {
                    let src = resolve(&absorbed, i);
                    remap[src].expect("input not yet emitted")
                })
                .collect();
            let new_id = if let Some((fop, _)) = &fused[n.id] {
                out.add(n.name.clone(), fop.clone(), inputs)
            } else {
                out.add(n.name.clone(), n.op.clone(), inputs)
            };
            remap[n.id] = Some(new_id);
        }
        out.output = remap[resolve(&absorbed, g.output)].unwrap();
        out
    }
}

/// Follow absorption links to the producing conv node.
fn resolve(absorbed: &[Option<NodeId>], mut id: NodeId) -> NodeId {
    while let Some(p) = absorbed[id] {
        id = p;
    }
    id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn count_kind(g: &Graph, name: &str) -> usize {
        g.nodes.iter().filter(|n| n.op.name() == name).count()
    }

    #[test]
    fn mobilenet_v1_fully_fuses() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let f = FusionPass.run(&g);
        f.validate().unwrap();
        // every conv + dw fused, zero bare bn/act remain
        assert_eq!(count_kind(&f, "batchnorm"), 0);
        assert_eq!(count_kind(&f, "activation"), 0);
        assert_eq!(count_kind(&f, "fused_conv_bn_act"), 14); // stem + 13 pw
        assert_eq!(count_kind(&f, "fused_dw_bn_act"), 13);
        // paper's fusion motivation: node count collapses ~3x
        assert!(f.len() * 2 < g.len());
    }

    #[test]
    fn resnet50_keeps_preactivation_adds() {
        let g = models::build("resnet50", 1).unwrap();
        let f = FusionPass.run(&g);
        f.validate().unwrap();
        // The c3/downsample BNs fuse (act=None); the post-add ReLU cannot
        // fuse into a conv (its producer is Add), so 16 block ReLUs + ...
        assert_eq!(count_kind(&f, "batchnorm"), 0);
        assert_eq!(count_kind(&f, "add"), 16);
        // every add's relu survives as a bare activation
        assert_eq!(count_kind(&f, "activation"), 16);
        assert_eq!(count_kind(&f, "conv2d"), 0);
        assert_eq!(count_kind(&f, "fused_conv_bn_act"), 53);
    }

    #[test]
    fn fusion_preserves_weight_count() {
        for name in ["resnet50", "mobilenet_v2", "inception_v3"] {
            let g = models::build(name, 1).unwrap();
            let f = FusionPass.run(&g);
            assert_eq!(g.weight_count(), f.weight_count(), "{name}");
        }
    }

    #[test]
    fn fusion_preserves_flops_shape() {
        // FLOPs change only by the folded BN/act epsilon (BN as separate
        // op costs 2/elem; folded costs 2/elem in the fused op): within 2%.
        let g = models::build("mobilenet_v2", 1).unwrap();
        let f = FusionPass.run(&g);
        let (a, b) = (g.flops() as f64, f.flops() as f64);
        assert!((a - b).abs() / a < 0.02, "{a} vs {b}");
    }

    #[test]
    fn classic_nets_without_bn_untouched_by_bn_fusion() {
        // LeNet/AlexNet/VGG have conv(bias)+relu, no BN: the conv+bn
        // matcher must not fire (bias convs are excluded).
        let g = models::build("vgg16", 1).unwrap();
        let f = FusionPass.run(&g);
        assert_eq!(count_kind(&f, "fused_conv_bn_act"), 0);
        assert_eq!(count_kind(&f, "conv2d"), 13);
    }
}
