//! Memory-layout transformation planning (paper §4: tiling, alignment,
//! padding of filter layouts).
//!
//! The plan assigns each weight-bearing node a `LayoutInfo`: the SIMD
//! alignment padding of its output-channel dimension, the tile shape the
//! kernels will iterate in, and the resulting padded weight bytes. The
//! executor and the cost model both consume the plan; the tuner can
//! override the tile choice per layer.

use crate::ir::ops::Op;
use crate::ir::{Graph, NodeId};
use crate::util::round_up;
use std::collections::BTreeMap;

/// SIMD vector width (f32 lanes) the layout aligns to. 8 = AVX2 on the
/// host; the Snapdragon's NEON is 4 — the device spec carries its own.
pub const SIMD_LANES: usize = 8;

/// Tile configuration for a GEMM-like kernel (rows of the patch matrix x
/// output channels x reduction depth), plus the register-unroll factor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileConfig {
    pub mc: usize,
    pub nc: usize,
    pub kc: usize,
    pub unroll: usize,
}

impl TileConfig {
    /// The untuned default every personality starts from.
    pub const DEFAULT: TileConfig = TileConfig { mc: 64, nc: 128, kc: 256, unroll: 8 };

    /// Working-set bytes of one tile iteration (A + B + C panels, f32).
    pub fn working_set_bytes(&self) -> usize {
        4 * (self.mc * self.kc + self.kc * self.nc + self.mc * self.nc)
    }

    /// Legal for a given cache budget and problem shape.
    pub fn legal(&self, m: usize, k: usize, n: usize, cache_bytes: usize) -> bool {
        self.mc >= 1
            && self.nc >= 1
            && self.kc >= 1
            && self.unroll >= 1
            && self.unroll <= self.nc
            && self.working_set_bytes() <= cache_bytes
            && self.mc <= round_up(m.max(1), 8)
            && self.nc <= round_up(n.max(1), 8)
            && self.kc <= round_up(k.max(1), 8)
    }
}

#[derive(Debug, Clone)]
pub struct LayoutInfo {
    /// Output channels padded to the SIMD width.
    pub cout_padded: usize,
    /// Weight bytes after padding (what the transformed layout stores).
    pub weight_bytes_padded: usize,
    /// Chosen tile (DEFAULT until the tuner overrides).
    pub tile: TileConfig,
    /// GEMM-view dims (m = output pixels, k = reduction, n = cout).
    pub gemm_m: usize,
    pub gemm_k: usize,
    pub gemm_n: usize,
}

#[derive(Debug, Clone, Default)]
pub struct LayoutPlan {
    pub per_node: BTreeMap<NodeId, LayoutInfo>,
}

impl LayoutPlan {
    pub fn get(&self, id: NodeId) -> Option<&LayoutInfo> {
        self.per_node.get(&id)
    }

    pub fn set_tile(&mut self, id: NodeId, tile: TileConfig) {
        if let Some(info) = self.per_node.get_mut(&id) {
            info.tile = tile;
        }
    }
}

/// Build the layout plan for a (post-pass) graph.
pub fn plan(graph: &Graph) -> LayoutPlan {
    let mut per_node = BTreeMap::new();
    for n in &graph.nodes {
        let (m, k, cout) = match &n.op {
            Op::Conv2d { kh, kw, cin, cout, groups, .. }
            | Op::FusedConvBnAct { kh, kw, cin, cout, groups, .. } => (
                n.shape.n() * n.shape.h() * n.shape.w(),
                kh * kw * (cin / groups),
                *cout,
            ),
            Op::Gemm { m, k, n: nn, .. } => (*m, *k, *nn),
            Op::FullyConnected { cin, cout, .. } => (n.shape.n(), *cin, *cout),
            Op::DepthwiseConv2d { kh, kw, c, .. }
            | Op::FusedDwBnAct { kh, kw, c, .. } => {
                // depthwise: no reduction over channels; model as m=pixels,
                // k=taps, n=channels for tiling purposes.
                (n.shape.n() * n.shape.h() * n.shape.w(), kh * kw, *c)
            }
            _ => continue,
        };
        let cout_padded = round_up(cout, SIMD_LANES);
        let weight_bytes_padded = k * cout_padded * 4;
        per_node.insert(
            n.id,
            LayoutInfo {
                cout_padded,
                weight_bytes_padded,
                tile: TileConfig::DEFAULT,
                gemm_m: m,
                gemm_k: k,
                gemm_n: cout,
            },
        );
    }
    LayoutPlan { per_node }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::passes::{conv1x1_gemm::Conv1x1ToGemm, fusion::FusionPass, Pass};

    #[test]
    fn plan_covers_all_weight_nodes() {
        let g = models::build("resnet50", 1).unwrap();
        let t = Conv1x1ToGemm.run(&FusionPass.run(&g));
        let p = plan(&t);
        let weight_nodes = t.nodes.iter().filter(|n| n.op.weight_count() > 0).count();
        assert_eq!(p.per_node.len(), weight_nodes);
    }

    #[test]
    fn padding_is_simd_aligned() {
        let g = models::build("lenet5", 1).unwrap();
        let p = plan(&g);
        for info in p.per_node.values() {
            assert_eq!(info.cout_padded % SIMD_LANES, 0);
            assert!(info.cout_padded >= info.gemm_n);
            assert!(info.weight_bytes_padded >= info.gemm_k * info.gemm_n * 4);
        }
    }

    #[test]
    fn tile_legality() {
        let t = TileConfig::DEFAULT;
        assert!(t.legal(1000, 1000, 1000, 512 * 1024));
        // too big for a 16KB budget
        assert!(!TileConfig { mc: 256, nc: 256, kc: 256, unroll: 4 }.legal(
            1000, 1000, 1000, 16 * 1024
        ));
        // unroll must not exceed nc
        assert!(!TileConfig { mc: 8, nc: 4, kc: 8, unroll: 8 }.legal(100, 100, 100, 1 << 20));
    }

    #[test]
    fn set_tile_overrides() {
        let g = models::build("lenet5", 1).unwrap();
        let mut p = plan(&g);
        let id = *p.per_node.keys().next().unwrap();
        let custom = TileConfig { mc: 32, nc: 16, kc: 128, unroll: 8 };
        p.set_tile(id, custom);
        assert_eq!(p.get(id).unwrap().tile, custom);
    }

    #[test]
    fn gemm_dims_match_conv_geometry() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let p = plan(&g);
        // stem conv: 3x3x3 -> 32 over 112x112 output
        let stem = g.nodes.iter().find(|n| n.name == "stem").unwrap();
        let info = p.get(stem.id).unwrap();
        assert_eq!(info.gemm_m, 112 * 112);
        assert_eq!(info.gemm_k, 27);
        assert_eq!(info.gemm_n, 32);
    }
}
