//! Redundant-load elimination analysis (paper §4: "many elements in
//! filters of convolution layers are repeatedly loaded to registers;
//! CADNN implements a compiler code transformation to eliminate such
//! redundant memory loads").
//!
//! We model register behaviour per weight-bearing node: a naive kernel
//! re-loads every filter element for every output pixel of its tile;
//! register-tiling by (mr x unroll) keeps the filter element resident
//! across `mr` output rows and `unroll` output columns. The analysis
//! yields naive vs optimized load counts; the cost model converts the
//! delta into saved bytes on the target's cache hierarchy, which is what
//! separates CADNN-D from TVM-like schedules in Figure 2.

use crate::ir::ops::Op;
use crate::ir::{Graph, NodeId};
use crate::passes::layout::{LayoutPlan, TileConfig};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadStats {
    /// Filter-element register loads in the naive schedule.
    pub naive_loads: u64,
    /// After register-tiling / load hoisting.
    pub optimized_loads: u64,
}

impl LoadStats {
    pub fn eliminated(&self) -> u64 {
        self.naive_loads - self.optimized_loads
    }
    pub fn reduction_factor(&self) -> f64 {
        self.naive_loads as f64 / self.optimized_loads.max(1) as f64
    }
}

/// Register-tile rows: how many output pixels a micro-kernel accumulates
/// per filter-element load (matches the native kernels' micro-tile).
pub const MICRO_ROWS: usize = 4;

/// Analyze one node under a tile configuration.
pub fn analyze_node(op: &Op, gemm_m: usize, gemm_k: usize, gemm_n: usize, tile: &TileConfig) -> Option<LoadStats> {
    match op {
        Op::Conv2d { .. }
        | Op::FusedConvBnAct { .. }
        | Op::Gemm { .. }
        | Op::FullyConnected { .. } => {
            // naive: every (k, n) weight element loaded once per output row m
            let naive = (gemm_m as u64) * (gemm_k as u64) * (gemm_n as u64);
            // optimized: loaded once per micro-tile of MICRO_ROWS x unroll
            // rows, i.e. m / MICRO_ROWS times, and hoisted across the
            // unrolled columns (already counted in n).
            let rows = gemm_m.div_ceil(MICRO_ROWS).max(1) as u64;
            let optimized = rows * (gemm_k as u64) * (gemm_n as u64) / tile.unroll.max(1) as u64;
            Some(LoadStats { naive_loads: naive, optimized_loads: optimized.max(1) })
        }
        Op::DepthwiseConv2d { kh, kw, c, .. } | Op::FusedDwBnAct { kh, kw, c, .. } => {
            let taps = (kh * kw * c) as u64;
            let pixels = (gemm_m as u64).max(1);
            Some(LoadStats {
                naive_loads: taps * pixels,
                optimized_loads: taps * pixels.div_ceil(MICRO_ROWS as u64).max(1),
            })
        }
        _ => None,
    }
}

/// Whole-graph analysis keyed by node id.
pub fn analyze(graph: &Graph, plan: &LayoutPlan) -> BTreeMap<NodeId, LoadStats> {
    let mut out = BTreeMap::new();
    for n in &graph.nodes {
        if let Some(info) = plan.get(n.id) {
            if let Some(stats) =
                analyze_node(&n.op, info.gemm_m, info.gemm_k, info.gemm_n, &info.tile)
            {
                out.insert(n.id, stats);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::passes::layout;

    #[test]
    fn conv_loads_reduced_by_micro_tile() {
        let op = Op::conv(3, 3, 16, 32, 1, 1);
        let stats = analyze_node(&op, 1024, 144, 32, &TileConfig::DEFAULT).unwrap();
        assert_eq!(stats.naive_loads, 1024 * 144 * 32);
        // 4-row micro tile x 8-wide unroll (DEFAULT) => 32x fewer
        assert!((stats.reduction_factor() - 32.0).abs() < 1.0);
    }

    #[test]
    fn whole_graph_analysis_nontrivial() {
        let g = models::build("resnet50", 1).unwrap();
        let p = layout::plan(&g);
        let stats = analyze(&g, &p);
        assert!(!stats.is_empty());
        let total_naive: u64 = stats.values().map(|s| s.naive_loads).sum();
        let total_opt: u64 = stats.values().map(|s| s.optimized_loads).sum();
        assert!(total_opt * 8 < total_naive, "expected >8x load elimination");
    }

    #[test]
    fn bigger_unroll_eliminates_more() {
        let op = Op::conv(3, 3, 16, 32, 1, 1);
        let t4 = TileConfig { unroll: 4, ..TileConfig::DEFAULT };
        let t8 = TileConfig { unroll: 8, ..TileConfig::DEFAULT };
        let s4 = analyze_node(&op, 4096, 144, 32, &t4).unwrap();
        let s8 = analyze_node(&op, 4096, 144, 32, &t8).unwrap();
        assert!(s8.optimized_loads < s4.optimized_loads);
    }

    #[test]
    fn elementwise_ops_have_no_stats() {
        assert!(analyze_node(&Op::Add, 10, 10, 10, &TileConfig::DEFAULT).is_none());
    }
}
