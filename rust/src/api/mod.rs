//! The unified front door: `EngineBuilder → Engine → Session`.
//!
//! The paper's CADNN framework is one pipeline — compress, optimize,
//! execute. This module is the one public API over that pipeline,
//! replacing the two disconnected entry points the repo grew up with
//! (positional-argument `ModelInstance::build` for native execution,
//! manifest-only `Runtime` for AOT artifacts):
//!
//! ```ignore
//! use cadnn::api::Engine;
//! use cadnn::exec::Personality;
//!
//! // native execution (always available)
//! let engine = Engine::native("lenet5")
//!     .personality(Personality::CadnnDense)
//!     .batch_sizes(&[1, 2, 4])
//!     .build()?;
//! let mut session = engine.session();
//! let logits = session.run(&image)?; // repeated runs reuse buffers
//!
//! // AOT artifacts (needs the real PJRT binding + `make artifacts`)
//! let engine = Engine::artifacts("artifacts", "lenet5", "dense").build()?;
//! ```
//!
//! An [`Engine`] is cheap to clone (shared state behind an `Arc`) and is
//! itself a [`Backend`], so it plugs straight into the multi-model
//! serving [`crate::serve::Server`] (`Server::builder().engine(...)`)
//! or the deprecated single-model `Coordinator` shim.
//! [`Session`]s opened from one engine share weights but lease dedicated
//! scratch buffers, so `session.run` in a loop stops reallocating the
//! per-node tensor table (see [`crate::exec::ExecScratch`]).

pub mod backend;

pub use backend::{ArtifactBackend, Backend, BackendStats, NativeBackend};

use crate::compress::profile::SparsityProfile;
use crate::error::CadnnError;
use crate::exec::{ModelInstance, Personality};
use crate::ir::Graph;
use crate::models;
use crate::planner::{db, ExecPlan, FormatPolicy, PlanCache, ValuePolicy};
use crate::tuner::TunerCache;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Where the engine's model comes from.
enum ModelSource {
    /// A named architecture from [`crate::models`], rebuilt per batch size.
    Named(String),
    /// A caller-supplied graph (fixed batch = the graph's input batch).
    Graph(Box<Graph>),
    /// A `.cadnn` textual model on disk ([`crate::front`]), rebatched
    /// per requested batch size via [`Graph::with_batch`].
    File { path: String },
    /// AOT artifacts on disk: (dir, model, variant).
    Artifacts { dir: String, model: String, variant: String },
}

/// Reject profiles that match nothing (the planner would silently plan
/// Dense for every layer — exactly the failure mode a renamed layer in a
/// compress report or `.cadnn` file used to hit); warn on partial
/// mismatches, listing the orphaned names.
fn check_profile_matches(profile: &SparsityProfile, g: &Graph) -> Result<(), CadnnError> {
    if profile.is_empty() {
        return Ok(());
    }
    let unmatched = profile.unmatched_layers(g);
    if unmatched.len() == profile.layers.len() {
        return Err(CadnnError::config(format!(
            "sparsity profile matches no prunable layer of '{}' (profile names e.g. {:?}); \
             every layer would plan Dense — profile layer names must equal graph node names",
            g.name,
            &unmatched[..unmatched.len().min(4)]
        )));
    }
    if !unmatched.is_empty() {
        let shown: Vec<&str> = unmatched.iter().take(8).map(String::as_str).collect();
        crate::warn!(
            "api",
            "profile layers {:?}{} match no prunable node of '{}' and will plan Dense",
            shown,
            if unmatched.len() > 8 { " (+more)" } else { "" },
            g.name
        );
    }
    Ok(())
}

/// Typed, named options for constructing an [`Engine`]. Replaces the old
/// five-positional-argument `ModelInstance::build` call at the public
/// boundary (which remains available as the low-level layer).
pub struct EngineBuilder {
    source: ModelSource,
    personality: Personality,
    profile: Option<SparsityProfile>,
    sparse_format: FormatPolicy,
    value_bits: ValuePolicy,
    tuned: bool,
    cache_bytes: usize,
    batch_sizes: Option<Vec<usize>>,
    threads: Option<usize>,
    plan_db: Option<String>,
    tune_plans: bool,
}

impl EngineBuilder {
    fn new(source: ModelSource) -> EngineBuilder {
        EngineBuilder {
            source,
            personality: Personality::CadnnDense,
            profile: None,
            sparse_format: FormatPolicy::Auto,
            value_bits: ValuePolicy::Auto,
            tuned: false,
            cache_bytes: 2 << 20,
            batch_sizes: None,
            threads: None,
            plan_db: None,
            tune_plans: false,
        }
    }

    /// Framework personality (passes + engine + tiles + weights). Default:
    /// [`Personality::CadnnDense`]. Native sources only.
    pub fn personality(mut self, p: Personality) -> EngineBuilder {
        self.personality = p;
        self
    }

    /// Per-layer sparsity for compressed execution. Requires
    /// [`Personality::CadnnSparse`]; `build` rejects other personalities.
    pub fn sparsity_profile(mut self, profile: SparsityProfile) -> EngineBuilder {
        self.profile = Some(profile);
        self
    }

    /// How pruned layers are stored and executed:
    /// [`FormatPolicy::Auto`] lets the planner pick Dense / CSR / BSR /
    /// Pattern per layer (default), [`FormatPolicy::Csr`] pins the
    /// pre-planner CSR baseline, [`FormatPolicy::Bsr`] pins block-sparse,
    /// [`FormatPolicy::Pattern`] pins the PatDNN pattern format on
    /// eligible spatial conv layers (others keep CSR). Non-`Auto` values
    /// require [`Personality::CadnnSparse`]; `build` rejects the
    /// combination otherwise.
    pub fn sparse_format(mut self, policy: FormatPolicy) -> EngineBuilder {
        self.sparse_format = policy;
        self
    }

    /// How sparse payloads store their *values* — the precision axis
    /// orthogonal to [`EngineBuilder::sparse_format`]:
    /// [`ValuePolicy::Auto`] follows the profile (layers whose compress
    /// report exported a codebook get quantized payloads at the exported
    /// width, everything else stays f32), [`ValuePolicy::F32`] pins raw
    /// floats, [`ValuePolicy::Q8`] / [`ValuePolicy::Q4`] pin codebook
    /// payloads executed through the LUT kernels. Non-`Auto` values
    /// require [`Personality::CadnnSparse`]; `build` rejects the
    /// combination otherwise. Dense-planned layers always stay f32.
    pub fn value_bits(mut self, policy: ValuePolicy) -> EngineBuilder {
        self.value_bits = policy;
        self
    }

    /// Run the optimization-parameter search per layer (slower build,
    /// faster inference). Default: off.
    pub fn tuned(mut self, on: bool) -> EngineBuilder {
        self.tuned = on;
        self
    }

    /// Cache budget (bytes) the tuner assumes for one macro-tile.
    /// Default: 2 MiB.
    pub fn cache_bytes(mut self, bytes: usize) -> EngineBuilder {
        self.cache_bytes = bytes;
        self
    }

    /// Batch sizes to build (named and `.cadnn` file models; the serving
    /// layer's dynamic batcher picks among them). Default: `[1]` for
    /// named models, the file's own input batch for file models.
    pub fn batch_sizes(mut self, sizes: &[usize]) -> EngineBuilder {
        self.batch_sizes = Some(sizes.to_vec());
        self
    }

    /// Hint the global kernel thread-pool size. Best-effort: only applies
    /// if no kernel has run yet in this process.
    pub fn threads(mut self, n: usize) -> EngineBuilder {
        self.threads = Some(n);
        self
    }

    /// Attach a persistent plan database (format in `docs/PLANDB.md`):
    /// layer plans whose spec — shape, sparsity structure, policies,
    /// device generation — matches a stored entry are answered from
    /// `path` without planning, and every cold search result is written
    /// back when the build finishes. A missing file starts cold; a
    /// corrupt or truncated file degrades to a cold search with a
    /// warning, never an error. Requires [`Personality::CadnnSparse`].
    pub fn plan_db(mut self, path: &str) -> EngineBuilder {
        self.plan_db = Some(path.to_string());
        self
    }

    /// Run the beam / branch-and-bound plan search with real kernel
    /// measurements per pruned layer ([`crate::planner::search`])
    /// instead of the one-shot heuristic. Combine with
    /// [`EngineBuilder::plan_db`] to persist the results: a warm
    /// database replans with zero measurements. Requires
    /// [`Personality::CadnnSparse`]. Default: off.
    pub fn tune_plans(mut self, on: bool) -> EngineBuilder {
        self.tune_plans = on;
        self
    }

    /// Validate the configuration and construct the engine.
    pub fn build(self) -> Result<Engine, CadnnError> {
        if let Some(n) = self.threads {
            crate::util::pool::request_threads(n);
        }
        if self.profile.is_some() && !self.personality.sparse() {
            return Err(CadnnError::config(
                "sparsity profile set but personality is not CadnnSparse",
            ));
        }
        if self.sparse_format != FormatPolicy::Auto && !self.personality.sparse() {
            return Err(CadnnError::config(
                "sparse_format pinned but personality is not CadnnSparse",
            ));
        }
        if self.value_bits != ValuePolicy::Auto && !self.personality.sparse() {
            return Err(CadnnError::config(
                "value_bits pinned but personality is not CadnnSparse",
            ));
        }
        if (self.plan_db.is_some() || self.tune_plans) && !self.personality.sparse() {
            return Err(CadnnError::config(
                "plan_db / tune_plans require the CadnnSparse personality",
            ));
        }
        // one plan cache for whichever native arm runs below; carries the
        // on-disk database and the tuning switch when configured
        let mut plan_cache = PlanCache::default();
        if let Some(path) = &self.plan_db {
            plan_cache.attach_db(db::PlanDb::open(path));
        }
        plan_cache.set_tune(self.tune_plans);
        match self.source {
            ModelSource::Named(name) => {
                let mut sizes = self.batch_sizes.clone().unwrap_or_else(|| vec![1]);
                sizes.sort_unstable();
                sizes.dedup();
                if sizes.is_empty() || sizes[0] == 0 {
                    return Err(CadnnError::config("batch sizes must be nonempty and nonzero"));
                }
                let mut cache = TunerCache::new();
                // the outer plan cache spans every batch variant: column
                // clustering, densification, pattern-library selection,
                // and (satellite of the plan database) the per-spec plan
                // memo run once per pruned layer, not once per variant
                // (weights are keyed by layer name, so variants share
                // them exactly)
                let mut instances = BTreeMap::new();
                for &b in &sizes {
                    let g = models::build(&name, b)
                        .ok_or_else(|| CadnnError::UnknownModel { name: name.clone() })?;
                    if b == sizes[0] {
                        if let Some(p) = &self.profile {
                            check_profile_matches(p, &g)?;
                        }
                    }
                    let inst = ModelInstance::build_planned_cached(
                        &g,
                        self.personality,
                        self.profile.as_ref(),
                        if self.tuned { Some(&mut cache) } else { None },
                        self.cache_bytes,
                        self.sparse_format,
                        self.value_bits,
                        Some(&mut plan_cache),
                    )?;
                    instances.insert(b, inst);
                }
                if let Err(e) = plan_cache.save_db() {
                    crate::warn!("api", "plan database not saved: {e}");
                }
                let label = format!("{name}[{}]", self.personality.label());
                let nb = Arc::new(NativeBackend::from_instances(label, instances)?);
                Ok(Engine {
                    backend: nb.clone(),
                    native: Some(nb),
                    tune: Some(plan_cache.tune_stats()),
                })
            }
            ModelSource::Graph(g) => {
                g.validate()?;
                if let Some(p) = &self.profile {
                    check_profile_matches(p, &g)?;
                }
                let graph_batch = g.nodes[0].shape.0.first().copied().unwrap_or(0);
                if let Some(sizes) = &self.batch_sizes {
                    if sizes.len() != 1 || sizes[0] != graph_batch {
                        return Err(CadnnError::config(format!(
                            "a fixed graph serves only its own input batch ({graph_batch}); \
                             use Engine::native(name) for batch variants"
                        )));
                    }
                }
                let mut cache = TunerCache::new();
                let inst = ModelInstance::build_planned_cached(
                    &g,
                    self.personality,
                    self.profile.as_ref(),
                    if self.tuned { Some(&mut cache) } else { None },
                    self.cache_bytes,
                    self.sparse_format,
                    self.value_bits,
                    Some(&mut plan_cache),
                )?;
                if let Err(e) = plan_cache.save_db() {
                    crate::warn!("api", "plan database not saved: {e}");
                }
                let label = format!("{}[{}]", g.name, self.personality.label());
                let mut instances = BTreeMap::new();
                instances.insert(graph_batch, inst);
                let nb = Arc::new(NativeBackend::from_instances(label, instances)?);
                Ok(Engine {
                    backend: nb.clone(),
                    native: Some(nb),
                    tune: Some(plan_cache.tune_stats()),
                })
            }
            ModelSource::File { path } => {
                let parsed = crate::front::parse_file(&path)?;
                parsed.graph.validate()?;
                // explicit builder profile wins over inline hints; hints
                // only attach under a sparse personality (they are a
                // compression request, meaningless to dense execution)
                let profile = match (&self.profile, self.personality.sparse()) {
                    (Some(p), _) => Some(p.clone()),
                    (None, true) if !parsed.profile.is_empty() => Some(parsed.profile.clone()),
                    (None, _) => {
                        if !parsed.profile.is_empty() {
                            crate::warn!(
                                "api",
                                "'{}' carries sparsity hints but personality {} is not sparse; \
                                 hints ignored",
                                path,
                                self.personality.label()
                            );
                        }
                        None
                    }
                };
                if let Some(p) = &profile {
                    check_profile_matches(p, &parsed.graph)?;
                }
                let file_batch = parsed.graph.nodes[0].shape.0.first().copied().unwrap_or(1);
                let mut sizes = self.batch_sizes.clone().unwrap_or_else(|| vec![file_batch]);
                sizes.sort_unstable();
                sizes.dedup();
                if sizes.is_empty() || sizes[0] == 0 {
                    return Err(CadnnError::config("batch sizes must be nonempty and nonzero"));
                }
                let mut cache = TunerCache::new();
                let mut instances = BTreeMap::new();
                for &b in &sizes {
                    let g = parsed.graph.with_batch(b)?;
                    let inst = ModelInstance::build_planned_cached(
                        &g,
                        self.personality,
                        profile.as_ref(),
                        if self.tuned { Some(&mut cache) } else { None },
                        self.cache_bytes,
                        self.sparse_format,
                        self.value_bits,
                        Some(&mut plan_cache),
                    )?;
                    instances.insert(b, inst);
                }
                if let Err(e) = plan_cache.save_db() {
                    crate::warn!("api", "plan database not saved: {e}");
                }
                let label = format!("{}[{}]", parsed.graph.name, self.personality.label());
                let nb = Arc::new(NativeBackend::from_instances(label, instances)?);
                Ok(Engine {
                    backend: nb.clone(),
                    native: Some(nb),
                    tune: Some(plan_cache.tune_stats()),
                })
            }
            ModelSource::Artifacts { dir, model, variant } => {
                if self.batch_sizes.is_some() {
                    return Err(CadnnError::config(
                        "artifact batch variants come from the manifest, not the builder",
                    ));
                }
                if self.plan_db.is_some() || self.tune_plans {
                    return Err(CadnnError::config(
                        "artifact engines are pre-planned; plan_db / tune_plans do not apply",
                    ));
                }
                // NOTE: with the real (non-stub) xla binding, PJRT handles
                // are not Sync; artifact engines would then need the
                // factory-based Coordinator::serve_with path instead.
                let backend = Arc::new(ArtifactBackend::open(&dir, &model, &variant)?);
                Ok(Engine { backend, native: None, tune: None })
            }
        }
    }
}

/// A ready-to-run model behind a pluggable [`Backend`]. Cheap to clone;
/// all clones share weights, compiled programs, and scratch pools.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend + Send + Sync>,
    native: Option<Arc<NativeBackend>>,
    /// Build-time planning counters (memo / database hits, searches,
    /// measurements). `None` for artifact engines, whose plans were
    /// fixed at compile time.
    tune: Option<db::TuneStats>,
}

impl Engine {
    /// Build a named model (see [`crate::models::all_names`]) on the
    /// native kernels.
    pub fn native(model: &str) -> EngineBuilder {
        EngineBuilder::new(ModelSource::Named(model.to_string()))
    }

    /// Build a caller-supplied graph on the native kernels.
    pub fn from_graph(graph: Graph) -> EngineBuilder {
        EngineBuilder::new(ModelSource::Graph(Box::new(graph)))
    }

    /// Build a `.cadnn` textual model file ([`crate::front`], grammar in
    /// `docs/MODEL_FORMAT.md`) on the native kernels. The file's inline
    /// `sparsity=` hints become the engine's profile under a sparse
    /// personality unless [`EngineBuilder::sparsity_profile`] overrides
    /// them; batch variants are built with [`Graph::with_batch`].
    pub fn from_model_file(path: &str) -> EngineBuilder {
        EngineBuilder::new(ModelSource::File { path: path.to_string() })
    }

    /// Open AOT artifacts compiled by `make artifacts`.
    pub fn artifacts(dir: &str, model: &str, variant: &str) -> EngineBuilder {
        EngineBuilder::new(ModelSource::Artifacts {
            dir: dir.to_string(),
            model: model.to_string(),
            variant: variant.to_string(),
        })
    }

    /// Open a session: a single-stream handle whose repeated `run` calls
    /// reuse intermediate buffers.
    pub fn session(&self) -> Session {
        Session { backend: self.backend.clone(), runs: 0 }
    }

    /// Backend identity (model/variant/personality).
    pub fn name(&self) -> &str {
        self.backend.name()
    }

    /// Per-image input shape (batch axis excluded).
    pub fn input_shape(&self) -> &[usize] {
        self.backend.input_shape()
    }

    /// Flat floats per image.
    pub fn input_len(&self) -> usize {
        self.backend.input_shape().iter().product()
    }

    /// Logits per image.
    pub fn classes(&self) -> usize {
        self.backend.classes()
    }

    /// Batch sizes this engine can execute, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.backend.batch_sizes()
    }

    /// Execution/buffer-reuse telemetry.
    pub fn stats(&self) -> BackendStats {
        self.backend.stats()
    }

    /// The per-layer execution plan behind this engine, when known (see
    /// [`Backend::exec_plan`]). This is what a serving registry entry
    /// carries next to the engine.
    pub fn exec_plan(&self) -> Option<ExecPlan> {
        self.backend.exec_plan()
    }

    /// Per-batch-variant plan costs (see [`Backend::plan_costs`]) — the
    /// scheduler prior behind `serve`'s deadline-aware batching.
    pub fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.backend.plan_costs()
    }

    /// The native backend, when this engine runs on the in-process
    /// kernels (profiling, weight inspection).
    pub fn native_backend(&self) -> Option<&NativeBackend> {
        self.native.as_deref()
    }

    /// Build-time plan-tuning counters: how many layer-planning requests
    /// were answered by the in-process memo, the plan database, or a
    /// cold search, and how many kernel measurements ran (see
    /// [`crate::planner::db::TuneStats`]). `None` for artifact engines.
    pub fn tune_stats(&self) -> Option<db::TuneStats> {
        self.tune
    }
}

/// An [`Engine`] is itself a [`Backend`], so it plugs directly into the
/// coordinator (`Coordinator::serve_engine`) or any other generic driver.
impl Backend for Engine {
    fn name(&self) -> &str {
        self.backend.name()
    }

    fn input_shape(&self) -> &[usize] {
        self.backend.input_shape()
    }

    fn classes(&self) -> usize {
        self.backend.classes()
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.backend.batch_sizes()
    }

    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        self.backend.run_batch(batch, input)
    }

    fn stats(&self) -> BackendStats {
        self.backend.stats()
    }

    fn exec_plan(&self) -> Option<ExecPlan> {
        self.backend.exec_plan()
    }

    fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.backend.plan_costs()
    }

    fn calibration(&self) -> Option<f64> {
        self.backend.calibration()
    }
}

/// Single-stream inference handle. `&mut self` expresses that a session
/// is one serial stream: each call leases the same scratch buffers back
/// from the engine's pool, so steady-state runs allocate nothing on the
/// per-node hot path.
pub struct Session {
    backend: Arc<dyn Backend + Send + Sync>,
    runs: u64,
}

impl Session {
    /// Classify one image (flat NHWC, `input_len` floats); returns
    /// `classes` logits.
    pub fn run(&mut self, image: &[f32]) -> Result<Vec<f32>, CadnnError> {
        self.run_batch(1, image)
    }

    /// Run a whole batch (must be one of `batch_sizes`).
    pub fn run_batch(&mut self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        let out = self.backend.run_batch(batch, input)?;
        self.runs += 1;
        Ok(out)
    }

    /// Completed runs on this session.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Per-image input shape (batch axis excluded).
    pub fn input_shape(&self) -> &[usize] {
        self.backend.input_shape()
    }

    /// Flat floats per image.
    pub fn input_len(&self) -> usize {
        self.backend.input_shape().iter().product()
    }

    /// Logits per image.
    pub fn classes(&self) -> usize {
        self.backend.classes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::profile::paper_profile;
    use crate::util::rng::Rng;

    fn image(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    #[test]
    fn engine_builds_and_runs_lenet5() {
        let engine = Engine::native("lenet5").build().unwrap();
        assert_eq!(engine.input_shape(), &[28, 28, 1]);
        assert_eq!(engine.classes(), 10);
        assert_eq!(engine.batch_sizes(), vec![1]);
        let mut session = engine.session();
        let logits = session.run(&image(engine.input_len(), 1)).unwrap();
        assert_eq!(logits.len(), 10);
        let s: f32 = logits.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "softmax rows sum to 1, got {s}");
        assert_eq!(session.runs(), 1);
    }

    #[test]
    fn unknown_model_is_typed_error() {
        match Engine::native("nope").build() {
            Err(CadnnError::UnknownModel { name }) => assert_eq!(name, "nope"),
            other => panic!("expected UnknownModel, got {:?}", other.err()),
        }
    }

    #[test]
    fn profile_requires_sparse_personality() {
        let g = models::build("lenet5", 1).unwrap();
        let err = Engine::native("lenet5")
            .sparsity_profile(paper_profile(&g))
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    #[test]
    fn pinned_sparse_format_requires_sparse_personality() {
        let err = Engine::native("lenet5")
            .sparse_format(FormatPolicy::Bsr)
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    #[test]
    fn pinned_value_bits_requires_sparse_personality() {
        let err = Engine::native("lenet5")
            .value_bits(ValuePolicy::Q4)
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    #[test]
    fn plan_db_requires_sparse_personality() {
        let err = Engine::native("lenet5").plan_db("x.json").build().err().unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
        let err = Engine::native("lenet5").tune_plans(true).build().err().unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    #[test]
    fn artifact_engine_rejects_plan_db() {
        let err = Engine::artifacts("artifacts", "lenet5", "dense")
            .personality(Personality::CadnnSparse)
            .plan_db("x.json")
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    /// The plan database end-to-end through the public API: a cold build
    /// writes its searched plans to disk, a rebuild answers every pruned
    /// layer from the database without searching, and the two engines'
    /// plans are bit-identical through the JSON round trip.
    #[test]
    fn plan_db_warm_rebuild_is_hit_only_and_identical() {
        let path =
            std::env::temp_dir().join(format!("cadnn_api_plandb_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let g = models::build("lenet5", 1).unwrap();
        let build = || {
            Engine::native("lenet5")
                .personality(Personality::CadnnSparse)
                .sparsity_profile(paper_profile(&g))
                .batch_sizes(&[1, 2])
                .plan_db(path.to_str().unwrap())
                .build()
                .unwrap()
        };
        let cold = build();
        let cs = cold.tune_stats().expect("native engines report tune stats");
        assert!(cs.searched > 0, "cold build must search: {cs:?}");
        assert_eq!(cs.measurements, 0, "database without tuning stays modeled: {cs:?}");
        let warm = build();
        std::fs::remove_file(&path).ok();
        let ws = warm.tune_stats().unwrap();
        assert_eq!(ws.searched, 0, "warm build must not search: {ws:?}");
        assert_eq!(ws.measurements, 0, "{ws:?}");
        assert!(ws.db_hits > 0, "{ws:?}");
        let a = cold.exec_plan().unwrap().to_json().to_string_pretty();
        let b = warm.exec_plan().unwrap().to_json().to_string_pretty();
        assert_eq!(a, b, "warm plans must be bit-identical to the cold run's");
    }

    /// The value axis end-to-end through the public API: a pinned Q8
    /// engine executes through the LUT kernels and agrees with the f32
    /// engine within the codebook error, and the plan records the width.
    #[test]
    fn quantized_engine_agrees_with_f32_within_bound() {
        use crate::compress::qsparse::ValueBits;
        let g = models::build("lenet5", 1).unwrap();
        let build = |vp: ValuePolicy| {
            Engine::native("lenet5")
                .personality(Personality::CadnnSparse)
                .sparsity_profile(paper_profile(&g))
                .value_bits(vp)
                .build()
                .unwrap()
        };
        let f = build(ValuePolicy::F32);
        let q = build(ValuePolicy::Q8);
        let plan = q.exec_plan().unwrap();
        assert!(
            plan.layers
                .values()
                .filter(|lp| lp.format != crate::planner::SparseFormat::Dense)
                .all(|lp| lp.value_bits == ValueBits::Q8),
            "pinned Q8 must reach every sparse layer: {plan:?}"
        );
        let img = image(f.input_len(), 23);
        let a = f.session().run(&img).unwrap();
        let b = q.session().run(&img).unwrap();
        // logits pass through softmax, which is 1-Lipschitz-ish in the
        // max-abs sense for bounded inputs; 8-bit codebooks keep the
        // pre-softmax drift tiny, so a loose tolerance is meaningful
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.05, "f32 {x} vs q8 {y}");
        }
    }

    #[test]
    fn sparse_format_policies_agree() {
        let g = models::build("lenet5", 1).unwrap();
        let build = |policy: FormatPolicy| {
            Engine::native("lenet5")
                .personality(Personality::CadnnSparse)
                .sparsity_profile(paper_profile(&g))
                .sparse_format(policy)
                .build()
                .unwrap()
        };
        let csr = build(FormatPolicy::Csr);
        let bsr = build(FormatPolicy::Bsr);
        let auto = build(FormatPolicy::Auto);
        let img = image(csr.input_len(), 21);
        let a = csr.session().run(&img).unwrap();
        let b = bsr.session().run(&img).unwrap();
        let c = auto.session().run(&img).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "csr {x} vs bsr {y}");
        }
        for (x, y) in a.iter().zip(&c) {
            assert!((x - y).abs() < 1e-3, "csr {x} vs auto {y}");
        }
    }

    #[test]
    fn batch_variants_and_unavailable_batch() {
        let engine = Engine::native("lenet5").batch_sizes(&[2, 1, 2]).build().unwrap();
        assert_eq!(engine.batch_sizes(), vec![1, 2]);
        let mut session = engine.session();
        let out = session.run_batch(2, &image(2 * engine.input_len(), 3)).unwrap();
        assert_eq!(out.len(), 20);
        match session.run_batch(4, &image(4 * engine.input_len(), 3)) {
            Err(CadnnError::BatchUnavailable { batch: 4, available }) => {
                assert_eq!(available, vec![1, 2]);
            }
            other => panic!("expected BatchUnavailable, got {:?}", other.err()),
        }
    }

    /// Engines surface the planner's cost model to the serving layer:
    /// the per-variant costs are exactly `ExecPlan::cost_at(b)`.
    #[test]
    fn engine_exposes_plan_and_costs() {
        let dense = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
        assert!(dense.exec_plan().is_none(), "nothing pruned -> no plan");
        assert!(dense.plan_costs().is_empty());

        let g = models::build("lenet5", 1).unwrap();
        let sparse = Engine::native("lenet5")
            .personality(Personality::CadnnSparse)
            .sparsity_profile(paper_profile(&g))
            .batch_sizes(&[1, 2, 4])
            .build()
            .unwrap();
        let plan = sparse.exec_plan().expect("pruned engine has a plan");
        assert!(!plan.is_empty());
        let costs = sparse.plan_costs();
        assert_eq!(costs.len(), 3, "one cost per batch variant: {costs:?}");
        for (b, c) in &costs {
            let from_plan = plan.cost_at(*b).expect("plan carries costs");
            assert!(
                (from_plan - c).abs() < 1e-6,
                "variant {b}: cost {c} != ExecPlan::cost_at {from_plan}"
            );
        }
        assert!(costs[2].1 > costs[0].1, "bigger batches cost more: {costs:?}");
    }

    #[test]
    fn sessions_share_one_engine() {
        let engine = Engine::native("lenet5").build().unwrap();
        let img = image(engine.input_len(), 5);
        let mut s1 = engine.session();
        let mut s2 = engine.session();
        let a = s1.run(&img).unwrap();
        let b = s2.run(&img).unwrap();
        assert_eq!(a, b, "sessions over one engine must agree");
    }

    #[test]
    fn repeated_session_runs_reuse_buffers() {
        let engine = Engine::native("lenet5").build().unwrap();
        let img = image(engine.input_len(), 7);
        let mut session = engine.session();
        let first = session.run(&img).unwrap();
        let after_one = engine.stats();
        assert!(after_one.buffer_allocs > 0);
        let second = session.run(&img).unwrap();
        let after_two = engine.stats();
        assert_eq!(first, second);
        assert!(
            after_two.buffer_reuses > after_one.buffer_reuses,
            "second run must reuse pooled buffers: {after_two:?}"
        );
        let third = session.run(&img).unwrap();
        let after_three = engine.stats();
        assert_eq!(first, third);
        assert_eq!(
            after_three.buffer_allocs, after_two.buffer_allocs,
            "steady state must not allocate fresh buffers"
        );
    }

    #[test]
    fn from_graph_serves_fixed_batch() {
        let g = models::build("lenet5", 2).unwrap();
        let engine = Engine::from_graph(g).personality(Personality::TvmLike).build().unwrap();
        assert_eq!(engine.batch_sizes(), vec![2]);
        let mut session = engine.session();
        let out = session.run_batch(2, &image(2 * engine.input_len(), 9)).unwrap();
        assert_eq!(out.len(), 20);
    }

    /// A `.cadnn` file is a complete engine input: inline hints become
    /// the profile under a sparse personality, batch variants come from
    /// `Graph::with_batch`, and the session answers with the file's
    /// output width.
    #[test]
    fn model_file_engine_end_to_end() {
        let path = std::env::temp_dir().join(format!("cadnn_api_{}.cadnn", std::process::id()));
        let src = "model filenet\n\
                   input x [1,8,8,3]\n\
                   c1 = conv2d(x) k=3 cout=16 pad=1 sparsity=0.9\n\
                   r1 = relu(c1)\n\
                   gap = global_avg_pool(r1)\n\
                   fc = dense(gap) cout=10 bias sparsity=0.8\n\
                   sm = softmax(fc)\n\
                   output sm\n";
        std::fs::write(&path, src).unwrap();
        let engine = Engine::from_model_file(path.to_str().unwrap())
            .personality(Personality::CadnnSparse)
            .batch_sizes(&[1, 2])
            .build()
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(engine.batch_sizes(), vec![1, 2]);
        assert_eq!(engine.classes(), 10);
        let plan = engine.exec_plan().expect("inline hints must yield a plan");
        assert!(!plan.is_empty(), "hinted layers must be planned: {plan:?}");
        let mut session = engine.session();
        let out = session.run_batch(2, &image(2 * engine.input_len(), 11)).unwrap();
        assert_eq!(out.len(), 20);
    }

    /// A profile whose layer names match nothing must fail the build
    /// loudly instead of silently planning Dense everywhere.
    #[test]
    fn mismatched_profile_fails_loudly() {
        let mut profile = SparsityProfile::default();
        profile.layers.insert("no_such_layer".into(), 0.9);
        let err = Engine::native("lenet5")
            .personality(Personality::CadnnSparse)
            .sparsity_profile(profile)
            .build()
            .err()
            .unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
        assert!(err.to_string().contains("matches no prunable layer"), "{err}");
    }

    #[test]
    fn missing_model_file_is_config_error() {
        let err = Engine::from_model_file("/nonexistent/nope.cadnn").build().err().unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
        assert!(err.to_string().contains("cannot read model file"), "{err}");
    }

    #[test]
    fn artifact_engine_unavailable_offline() {
        // with the stub xla binding, artifact engines must fail loudly and
        // typed — never panic
        let err = Engine::artifacts("artifacts", "lenet5", "dense").build().err().unwrap();
        assert!(
            matches!(err, CadnnError::BackendUnavailable { .. }),
            "expected BackendUnavailable, got {err}"
        );
    }
}
