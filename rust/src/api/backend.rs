//! The pluggable execution backend beneath [`crate::api::Engine`].
//!
//! A [`Backend`] answers three questions — what shape of image it takes,
//! how many classes it emits, and which batch sizes it can execute — and
//! runs flat batches. Two implementations ship:
//!
//! - [`NativeBackend`] wraps [`ModelInstance`]s built per batch size and
//!   executes on the in-process kernels (always available);
//! - [`ArtifactBackend`] wraps the PJRT [`Runtime`] over AOT-compiled HLO
//!   artifacts (available when the real `xla` binding is linked).
//!
//! The serving [`crate::serve::Server`] (and its deprecated single-model
//! shim [`crate::coordinator::Coordinator`]) is generic over
//! `Box<dyn Backend>`, so the dynamic batcher works identically for
//! both; [`Backend::exec_plan`] / [`Backend::plan_costs`] surface the
//! planner's cost model to the server's deadline-aware scheduler.

use crate::error::CadnnError;
use crate::exec::{ExecScratch, ModelInstance, Personality};
use crate::planner::ExecPlan;
use crate::runtime::{ManifestEntry, Runtime};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Execution telemetry, primarily buffer-reuse counters for the native
/// scratch pool.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BackendStats {
    /// Completed `run_batch` calls.
    pub runs: u64,
    /// Fresh intermediate-tensor allocations.
    pub buffer_allocs: u64,
    /// Intermediate tensors served from the reuse pool.
    pub buffer_reuses: u64,
}

/// A model execution engine the [`crate::api::Engine`] / coordinator can
/// drive. Object-safe; implementations decide how batches actually run.
pub trait Backend {
    /// Human-readable identity (model/variant).
    fn name(&self) -> &str;

    /// Per-image input shape, batch axis excluded (e.g. `[28, 28, 1]`).
    fn input_shape(&self) -> &[usize];

    /// Logits per image.
    fn classes(&self) -> usize;

    /// Ascending batch sizes this backend can execute.
    fn batch_sizes(&self) -> Vec<usize>;

    /// Execute a flat NHWC batch (`batch * input_shape.product()` floats);
    /// returns `batch * classes` logits.
    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError>;

    /// Telemetry; defaults to zeroes for backends that don't track it.
    fn stats(&self) -> BackendStats {
        BackendStats::default()
    }

    /// The per-layer execution plan behind this backend, when known
    /// (native engines: the smallest batch variant's plan; artifact
    /// backends: the manifest's plan). `None` when planning never ran or
    /// nothing was pruned.
    fn exec_plan(&self) -> Option<ExecPlan> {
        None
    }

    /// `(batch size, plan cost units)` per batch variant —
    /// [`ExecPlan::cost_at`] evaluated at each variant's batch size, the
    /// prior the serving scheduler ([`crate::serve::Scheduler`]) maps to
    /// microseconds from observed exec times. Empty when no cost model
    /// exists.
    fn plan_costs(&self) -> Vec<(usize, f64)> {
        Vec::new()
    }

    /// Persisted serving-cost calibration (µs per plan cost unit), when
    /// one exists — artifact backends read the manifest's `us_per_unit`
    /// so a fresh process's scheduler is deadline-accurate from its
    /// first batch. `None` when never served or not persisted.
    fn calibration(&self) -> Option<f64> {
        None
    }
}

/// Native-kernel backend: one [`ModelInstance`] per batch size, with a
/// pool of [`ExecScratch`]es so repeated runs (one session, or the
/// coordinator's serve loop) reuse intermediate buffers instead of
/// reallocating the per-node value table every call.
pub struct NativeBackend {
    name: String,
    instances: BTreeMap<usize, ModelInstance>,
    scratch: Mutex<BTreeMap<usize, Vec<ExecScratch>>>,
    input_shape: Vec<usize>,
    classes: usize,
    runs: AtomicU64,
    // monotonic telemetry: per-run deltas accumulated when a leased
    // scratch is returned, so in-flight scratches can't make stats()
    // regress between calls
    buffer_allocs: AtomicU64,
    buffer_reuses: AtomicU64,
}

impl NativeBackend {
    /// Assemble from prebuilt instances keyed by batch size (the
    /// [`crate::api::EngineBuilder`] does this).
    pub(crate) fn from_instances(
        name: String,
        instances: BTreeMap<usize, ModelInstance>,
    ) -> Result<NativeBackend, CadnnError> {
        let first = instances
            .values()
            .next()
            .ok_or_else(|| CadnnError::config("no batch variants built"))?;
        let in_full = &first.graph.nodes[0].shape.0;
        if in_full.len() < 2 {
            return Err(CadnnError::config("model input must have a batch axis"));
        }
        let input_shape = in_full[1..].to_vec();
        let out_shape = &first.graph.nodes[first.graph.output].shape.0;
        let classes = out_shape.last().copied().unwrap_or(0);
        for (&b, inst) in &instances {
            let got = inst.graph.nodes[0].shape.0[0];
            if got != b {
                return Err(CadnnError::config(format!(
                    "instance keyed as batch {b} has input batch {got}"
                )));
            }
        }
        Ok(NativeBackend {
            name,
            instances,
            scratch: Mutex::new(BTreeMap::new()),
            input_shape,
            classes,
            runs: AtomicU64::new(0),
            buffer_allocs: AtomicU64::new(0),
            buffer_reuses: AtomicU64::new(0),
        })
    }

    /// Return a leased scratch, folding its per-run counter deltas into
    /// the backend's monotonic totals.
    fn return_scratch(&self, batch: usize, scratch: ExecScratch, allocs0: u64, reuses0: u64) {
        self.buffer_allocs
            .fetch_add(scratch.buffer_allocs().saturating_sub(allocs0), Ordering::Relaxed);
        self.buffer_reuses
            .fetch_add(scratch.buffer_reuses().saturating_sub(reuses0), Ordering::Relaxed);
        self.scratch.lock().unwrap().entry(batch).or_default().push(scratch);
    }

    /// The instance serving a given batch size (advanced use: profiling,
    /// weight inspection).
    pub fn instance(&self, batch: usize) -> Option<&ModelInstance> {
        self.instances.get(&batch)
    }

    /// The personality every instance was built under.
    pub fn personality(&self) -> Personality {
        self.instances
            .values()
            .next()
            .map(|i| i.personality)
            .unwrap_or(Personality::CadnnDense)
    }

    fn per_image(&self) -> usize {
        self.input_shape.iter().product()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.instances.keys().copied().collect()
    }

    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        let inst = self.instances.get(&batch).ok_or_else(|| CadnnError::BatchUnavailable {
            batch,
            available: self.batch_sizes(),
        })?;
        let want = batch * self.per_image();
        if input.len() != want {
            return Err(CadnnError::InvalidInput {
                reason: format!("input length {} != batch {batch} * image {}", input.len(),
                    self.per_image()),
            });
        }
        // lease a scratch: a serial caller gets the same one back every
        // run (full buffer reuse); concurrent callers each get their own.
        let leased = {
            let mut pools = self.scratch.lock().unwrap();
            pools.get_mut(&batch).and_then(|v| v.pop())
        };
        let mut scratch = leased.unwrap_or_else(|| inst.scratch());
        let (allocs0, reuses0) = (scratch.buffer_allocs(), scratch.buffer_reuses());
        let result = inst.execute_slice(input, &mut scratch);
        let out = match result {
            Ok(out) => out,
            Err(e) => {
                self.return_scratch(batch, scratch, allocs0, reuses0);
                return Err(e);
            }
        };
        let logits = out.data.clone();
        scratch.recycle(out);
        self.runs.fetch_add(1, Ordering::Relaxed);
        self.return_scratch(batch, scratch, allocs0, reuses0);
        Ok(logits)
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            runs: self.runs.load(Ordering::Relaxed),
            buffer_allocs: self.buffer_allocs.load(Ordering::Relaxed),
            buffer_reuses: self.buffer_reuses.load(Ordering::Relaxed),
        }
    }

    fn exec_plan(&self) -> Option<ExecPlan> {
        self.instances
            .values()
            .next()
            .map(|i| i.plan.clone())
            .filter(|p| !p.is_empty())
    }

    fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.instances
            .iter()
            .filter_map(|(&b, inst)| inst.plan_cost().map(|c| (b, c)))
            .collect()
    }
}

/// PJRT artifact backend: AOT-compiled (model, variant) batch programs
/// loaded from an artifacts directory. With the offline `xla` stub this
/// constructor fails with [`CadnnError::BackendUnavailable`]; with the
/// real binding it serves compiled HLO.
pub struct ArtifactBackend {
    name: String,
    rt: Runtime,
    model: String,
    variant: String,
    input_shape: Vec<usize>,
    classes: usize,
}

impl ArtifactBackend {
    /// Open an artifacts directory and compile every batch variant of
    /// (model, variant).
    pub fn open(artifacts_dir: &str, model: &str, variant: &str) -> Result<ArtifactBackend, CadnnError> {
        let unavailable = |e: anyhow::Error| CadnnError::BackendUnavailable {
            backend: "pjrt-artifact".into(),
            reason: e.to_string(),
        };
        let mut rt = Runtime::open(artifacts_dir).map_err(unavailable)?;
        rt.load(model, variant).map_err(unavailable)?;
        let batches = rt.batches(model, variant);
        let entry = rt
            .get(model, variant, batches[0])
            .ok_or_else(|| CadnnError::BackendUnavailable {
                backend: "pjrt-artifact".into(),
                reason: format!("no loaded batch variants for {model}/{variant}"),
            })?
            .entry
            .clone();
        if entry.input_shape.len() < 2 {
            return Err(CadnnError::Manifest {
                reason: format!("entry {model}/{variant} input_shape lacks a batch axis"),
            });
        }
        Ok(ArtifactBackend {
            name: format!("{model}/{variant}@{artifacts_dir}"),
            rt,
            model: model.to_string(),
            variant: variant.to_string(),
            input_shape: entry.input_shape[1..].to_vec(),
            classes: entry.classes,
        })
    }

    /// Manifest metadata for one batch variant.
    pub fn manifest_entry(&self, batch: usize) -> Option<&ManifestEntry> {
        self.rt.get(&self.model, &self.variant, batch).map(|m| &m.entry)
    }
}

impl Backend for ArtifactBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.rt.batches(&self.model, &self.variant)
    }

    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        let model = self.rt.get(&self.model, &self.variant, batch).ok_or_else(|| {
            CadnnError::BatchUnavailable { batch, available: self.batch_sizes() }
        })?;
        model
            .run(input)
            .map_err(|e| CadnnError::Execution { reason: e.to_string() })
    }

    fn exec_plan(&self) -> Option<ExecPlan> {
        let b = *self.batch_sizes().first()?;
        self.manifest_entry(b)
            .and_then(|e| e.exec_plan.clone())
            .filter(|p| !p.is_empty())
    }

    fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.batch_sizes()
            .into_iter()
            .filter_map(|b| {
                let plan = self.manifest_entry(b)?.exec_plan.as_ref()?;
                plan.cost_at(b).map(|c| (b, c))
            })
            .collect()
    }

    fn calibration(&self) -> Option<f64> {
        // any batch variant carries the (model, variant)-level value;
        // take the first that has one
        self.batch_sizes()
            .into_iter()
            .find_map(|b| self.manifest_entry(b).and_then(|e| e.us_per_unit))
    }
}
