//! Shared bench harness: regenerates the paper's figures and tables
//! (DESIGN.md §5-6). Used by `cargo bench` targets, `examples/` and the
//! CLI so every entry point prints identical numbers.

pub mod figure2;
pub mod table2;

pub use figure2::{figure2, Figure2Row};
pub use table2::{table2, Table2Row};

/// Fixed-width table printer for paper-style output.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        s.trim_end().to_string()
    };
    println!("{}", line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", line(row));
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn print_table_smoke() {
        super::print_table(
            &["a", "bbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
