//! Figure 2 regeneration: inference latency of the four evaluation DNNs
//! under the seven (framework x device) configurations.
//!
//! Methodology (DESIGN.md §6): per-layer work/bytes from the exact IR
//! graphs; per-schedule efficiency ratios from host-measured kernels
//! (or the nominal table for reproducible output); roofline projection
//! onto the Snapdragon 835 CPU / Adreno 540 GPU descriptors.

use crate::compress::profile::paper_profile;
use crate::costmodel::{devices, graph_cost, CalibrationTable};
use crate::models;

#[derive(Debug, Clone)]
pub struct Figure2Row {
    pub model: String,
    pub series: &'static str,
    pub latency_ms: f64,
}

/// The paper's seven series.
pub const SERIES: [&str; 7] = [
    "CADNN-DC", "CADNN-DG", "CADNN-SC", "CADNN-SG", "TFLITE-DC", "TVM-DC", "TVM-DG",
];

/// Generate all Figure 2 rows. `tuning_uplift` is the measured
/// tuned-vs-default GEMM ratio (CADNN's §4.3 advantage over the
/// TVM-like default schedule); pass 1.0 to ablate.
pub fn figure2(calib: &CalibrationTable, tuning_uplift: f64) -> Vec<Figure2Row> {
    let cpu = devices::snapdragon835_cpu();
    let gpu = devices::adreno540_gpu();
    let cadnn = calib.clone().with_tuning_uplift(tuning_uplift);
    let mut rows = Vec::new();
    for name in models::EVAL_MODELS {
        let g = models::build(name, 1).unwrap();
        let profile = paper_profile(&g);
        let mut push = |series: &'static str, us: f64| {
            rows.push(Figure2Row { model: name.into(), series, latency_ms: us / 1e3 });
        };
        // CADNN dense: all optimizations, no sparsity
        push("CADNN-DC", graph_cost(&g, &cpu, &cadnn, false, None, None).0);
        push("CADNN-DG", graph_cost(&g, &gpu, &cadnn, false, None, None).0);
        // CADNN sparse: + compression profile
        push("CADNN-SC", graph_cost(&g, &cpu, &cadnn, false, Some(&profile), None).0);
        push("CADNN-SG", graph_cost(&g, &gpu, &cadnn, false, Some(&profile), None).0);
        // TFLite-like: dense, unfused, direct conv, CPU only
        push("TFLITE-DC", graph_cost(&g, &cpu, calib, true, None, None).0);
        // TVM-like: dense, fused+gemm, default tiles
        push("TVM-DC", graph_cost(&g, &cpu, calib, false, None, None).0);
        push("TVM-DG", graph_cost(&g, &gpu, calib, false, None, None).0);
    }
    rows
}

/// Paper headline checks derived from the rows.
pub struct Headline {
    pub resnet50_sc_ms: f64,
    pub resnet50_sg_ms: f64,
    pub inception_best_ms: f64,
    pub max_speedup_vs_tflite: f64,
    pub max_speedup_vs_tvm: f64,
}

pub fn headline(rows: &[Figure2Row]) -> Headline {
    let get = |model: &str, series: &str| -> f64 {
        rows.iter()
            .find(|r| r.model == model && r.series == series)
            .map(|r| r.latency_ms)
            .unwrap_or(f64::NAN)
    };
    let mut max_tfl: f64 = 0.0;
    let mut max_tvm: f64 = 0.0;
    for m in models::EVAL_MODELS {
        let best_cadnn = ["CADNN-DC", "CADNN-SC"]
            .iter()
            .map(|s| get(m, s))
            .fold(f64::INFINITY, f64::min);
        let best_cadnn_g = ["CADNN-DG", "CADNN-SG"]
            .iter()
            .map(|s| get(m, s))
            .fold(f64::INFINITY, f64::min);
        max_tfl = max_tfl.max(get(m, "TFLITE-DC") / best_cadnn);
        max_tvm = max_tvm
            .max(get(m, "TVM-DC") / best_cadnn)
            .max(get(m, "TVM-DG") / best_cadnn_g);
    }
    Headline {
        resnet50_sc_ms: get("resnet50", "CADNN-SC"),
        resnet50_sg_ms: get("resnet50", "CADNN-SG"),
        inception_best_ms: get("inception_v3", "CADNN-SG").min(get("inception_v3", "CADNN-SC")),
        max_speedup_vs_tflite: max_tfl,
        max_speedup_vs_tvm: max_tvm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<Figure2Row> {
        figure2(&CalibrationTable::nominal(), 1.25)
    }

    #[test]
    fn all_series_all_models() {
        let r = rows();
        assert_eq!(r.len(), 4 * 7);
        for m in models::EVAL_MODELS {
            for s in SERIES {
                assert!(
                    r.iter().any(|row| row.model == m && row.series == s),
                    "{m}/{s} missing"
                );
            }
        }
    }

    /// Figure 2's qualitative shape: CADNN wins everywhere; sparse beats
    /// dense; TFLite is the slowest CPU series.
    #[test]
    fn ordering_matches_paper() {
        let r = rows();
        let get = |m: &str, s: &str| {
            r.iter().find(|x| x.model == m && x.series == s).unwrap().latency_ms
        };
        for m in models::EVAL_MODELS {
            assert!(get(m, "CADNN-DC") < get(m, "TVM-DC"), "{m} cadnn<tvm cpu");
            assert!(get(m, "CADNN-DG") < get(m, "TVM-DG"), "{m} cadnn<tvm gpu");
            assert!(get(m, "TVM-DC") < get(m, "TFLITE-DC"), "{m} tvm<tflite");
            assert!(get(m, "CADNN-SC") < get(m, "CADNN-DC"), "{m} sparse<dense");
            assert!(get(m, "CADNN-SG") < get(m, "CADNN-DG"), "{m} sparse<dense gpu");
        }
    }

    /// Headline claims land in the paper's band (order of magnitude —
    /// our substrate is a projection, DESIGN.md §2): ResNet-50 compressed
    /// in the tens of ms, speedups in the single-digit-to-~10x range.
    #[test]
    fn headline_in_band() {
        let h = headline(&rows());
        assert!(
            h.resnet50_sc_ms > 5.0 && h.resnet50_sc_ms < 120.0,
            "resnet50 SC {} ms",
            h.resnet50_sc_ms
        );
        assert!(h.max_speedup_vs_tflite > 3.0, "{}", h.max_speedup_vs_tflite);
        assert!(h.max_speedup_vs_tflite < 30.0);
        assert!(h.max_speedup_vs_tvm > 1.5, "{}", h.max_speedup_vs_tvm);
    }
}
