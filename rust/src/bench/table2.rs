//! Table 2 regeneration: model name, Size(M), top-1/top-5 (quoted —
//! ImageNet accuracy is not re-measurable here, DESIGN.md §2), layer
//! counts under two conventions.

use crate::models;

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub model: &'static str,
    pub size_mb: f64,
    pub paper_size_mb: f64,
    /// paper-quoted accuracies (not re-measured — no ImageNet)
    pub top1: f64,
    pub top5: f64,
    /// weight layers (conv + dwconv + fc)
    pub weight_layers: usize,
    /// all compute nodes (conv/bn/act/pool/fc/add/concat) — closer to the
    /// paper's looser "Layer" counting
    pub compute_layers: usize,
    pub paper_layers: usize,
}

pub fn table2() -> Vec<Table2Row> {
    let paper: [(&str, f64, f64, f64, usize); 4] = [
        ("mobilenet_v1", 17.1, 70.9, 89.9, 31),
        ("mobilenet_v2", 14.1, 71.9, 91.0, 66),
        ("inception_v3", 95.4, 78.0, 93.9, 126),
        ("resnet50", 102.4, 75.2, 92.2, 94),
    ];
    paper
        .iter()
        .map(|&(name, size, top1, top5, layers)| {
            let g = models::build(name, 1).unwrap();
            let compute_layers = g
                .nodes
                .iter()
                .filter(|n| {
                    !matches!(
                        n.op,
                        crate::ir::Op::Input { .. }
                            | crate::ir::Op::Flatten
                            | crate::ir::Op::Softmax
                    )
                })
                .count();
            Table2Row {
                model: name,
                size_mb: g.size_mb(),
                paper_size_mb: size,
                top1,
                top5,
                weight_layers: g.weight_layer_count(),
                compute_layers,
                paper_layers: layers,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_within_2pct_of_paper() {
        for row in table2() {
            let rel = (row.size_mb - row.paper_size_mb).abs() / row.paper_size_mb;
            assert!(rel < 0.02, "{}: {} vs {}", row.model, row.size_mb, row.paper_size_mb);
        }
    }

    #[test]
    fn layer_counts_bracket_paper() {
        // The paper's "Layer" convention is looser than weight-layers and
        // tighter than all-compute-nodes; ours must bracket it.
        for row in table2() {
            assert!(
                row.weight_layers <= row.paper_layers,
                "{}: weight {} > paper {}",
                row.model,
                row.weight_layers,
                row.paper_layers
            );
            assert!(
                row.compute_layers >= row.paper_layers / 2,
                "{}: compute {} << paper {}",
                row.model,
                row.compute_layers,
                row.paper_layers
            );
        }
    }
}
