//! User-defined models end to end: parse a `.cadnn` file, plan its
//! hinted layers, and serve it — no Rust code per architecture.
//!
//! Defaults to the checked-in golden `models/resnet50.cadnn` (hint-free,
//! so the paper's §3 profile is attached for planning); point it at
//! your own file to run the full compress → plan → serve pipeline on a
//! model this repo has never seen (see `docs/MODEL_FORMAT.md`).
//!
//! ```sh
//! cargo run --release --example model_file [-- path/to/model.cadnn]
//! ```

use anyhow::Result;
use cadnn::api::Engine;
use cadnn::compress::profile::paper_profile;
use cadnn::exec::Personality;
use cadnn::front;
use cadnn::util::rng::Rng;
use cadnn::util::Stopwatch;

fn main() -> Result<()> {
    let path = std::env::args().nth(1).unwrap_or_else(|| "models/resnet50.cadnn".into());

    // what did we just read? (parse once here for reporting; the
    // builder parses again internally)
    let parsed = front::parse_file(&path)?;
    println!(
        "{path}: model '{}', {} nodes, {} weights, {} inline hints",
        parsed.graph.name,
        parsed.graph.nodes.len(),
        parsed.graph.weight_count(),
        parsed.profile.layers.len()
    );

    // hinted files carry their own per-layer profile; hint-free files
    // get the paper's §3 profile so the planner has something to chew on
    let mut builder = Engine::from_model_file(&path).personality(Personality::CadnnSparse);
    if parsed.profile.is_empty() {
        builder = builder.sparsity_profile(paper_profile(&parsed.graph));
    }
    let engine = builder.build()?;
    println!(
        "engine: {} — input {:?} -> {} classes",
        engine.name(),
        engine.input_shape(),
        engine.classes()
    );
    if let Some(plan) = engine.exec_plan() {
        println!("plan: {} pruned layers, formats {:?}", plan.len(), plan.format_counts());
    }

    // warmup + one timed inference on a deterministic random image
    let mut image = vec![0.0f32; engine.input_len()];
    Rng::new(7).fill_normal(&mut image, 0.5);
    let mut session = engine.session();
    let _ = session.run(&image)?;
    let sw = Stopwatch::new();
    let out = session.run(&image)?;
    let us = sw.elapsed_us();

    let pred = out
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!("prediction: class {pred} in {:.2} ms", us / 1e3);
    Ok(())
}
