//! End-to-end serving driver (DESIGN.md §5 "Serving E2E"): register the
//! dense AND compressed lenet5 variants in ONE multi-model
//! `serve::Server`, replay an interleaved Poisson request trace of
//! synthetic digit images, and report per-model latency percentiles,
//! throughput, batch utilization, and trace accuracy.
//!
//! Each variant serves the AOT artifacts when present (`make artifacts`
//! + real PJRT) via a factory-built backend inside that model's worker
//! thread; otherwise the same server batches over the native-kernel
//! engine through the `Backend` trait — no artifacts directory
//! required. (Native weights are synthetic, so trace accuracy is only
//! meaningful on the trained artifact path.) The sparse variant carries
//! an `ExecPlan`, so its batch sizes come from the planner cost model;
//! requests opt into a deadline and top-1 via `ServeRequest`.
//!
//! ```sh
//! cargo run --release --example serve_classifier [-- <requests> <rps>]
//! ```

use anyhow::Result;
use cadnn::api::{ArtifactBackend, Backend, Engine};
use cadnn::compress::profile::paper_profile;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::serve::{QueueConfig, ServeError, ServeRequest, Server};
use cadnn::util::rng::Rng;

/// Rasterize the same seven-segment procedural digits as
/// python/compile/datasets.py (one glyph, random offset, light noise) so
/// the served model sees in-distribution images.
fn digit_image(digit: usize, rng: &mut Rng) -> Vec<f32> {
    const SEGS: [(usize, usize, usize, usize); 7] = [
        (0, 2, 1, 11),
        (1, 10, 0, 2),
        (1, 10, 10, 12),
        (9, 11, 1, 11),
        (10, 19, 0, 2),
        (10, 19, 10, 12),
        (18, 20, 1, 11),
    ];
    const ON: [[u8; 7]; 10] = [
        [1, 1, 1, 0, 1, 1, 1],
        [0, 0, 1, 0, 0, 1, 0],
        [1, 0, 1, 1, 1, 0, 1],
        [1, 0, 1, 1, 0, 1, 1],
        [0, 1, 1, 1, 0, 1, 0],
        [1, 1, 0, 1, 0, 1, 1],
        [1, 1, 0, 1, 1, 1, 1],
        [1, 0, 1, 0, 0, 1, 0],
        [1, 1, 1, 1, 1, 1, 1],
        [1, 1, 1, 1, 0, 1, 1],
    ];
    let mut img = vec![0.0f32; 28 * 28];
    let (r0, c0) = (rng.range(0, 8), rng.range(0, 16));
    for (s, &(a, b, c, d)) in SEGS.iter().enumerate() {
        if ON[digit][s] == 1 {
            for r in a..b {
                for cc in c..d {
                    img[(r0 + r) * 28 + (c0 + cc)] = 0.85;
                }
            }
        }
    }
    for v in img.iter_mut() {
        *v = (*v + rng.normal() as f32 * 0.08).clamp(0.0, 1.0);
    }
    img
}

/// Register one lenet5 variant: an artifact-backed worker (the factory
/// runs inside the worker thread, as real PJRT handles require) or a
/// native engine.
fn register(
    builder: cadnn::serve::ServerBuilder,
    variant: &'static str,
    use_artifacts: bool,
    cfg: QueueConfig,
) -> Result<cadnn::serve::ServerBuilder> {
    if use_artifacts {
        return Ok(builder.backend_with(
            variant,
            move || {
                ArtifactBackend::open("artifacts", "lenet5", variant)
                    .map(|b| -> Box<dyn Backend> { Box::new(b) })
            },
            cfg,
        ));
    }
    let mut eb = Engine::native("lenet5").batch_sizes(&[1, 2, 4, 8]);
    if variant == "sparse" {
        let g = models::build("lenet5", 1).expect("lenet5 exists");
        eb = eb
            .personality(Personality::CadnnSparse)
            .sparsity_profile(paper_profile(&g));
    }
    Ok(builder.engine_with(variant, &eb.build()?, cfg))
}

/// Both variants behind one server; artifact path when requested.
fn build_server(use_artifacts: bool, cfg: QueueConfig) -> Result<Server> {
    let mut builder = Server::builder();
    for variant in ["dense", "sparse"] {
        builder = register(builder, variant, use_artifacts, cfg)?;
    }
    Ok(builder.build()?)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let rps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60.0);

    println!(
        "=== serve_classifier: one Server, dense + compressed lenet5, \
         {requests} reqs/variant @ {rps} req/s ===\n"
    );
    let cfg = QueueConfig { max_batch: 8, max_wait_us: 2_000, ..QueueConfig::default() };
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let server = if have_artifacts {
        match build_server(true, cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("(artifact path failed: {e}; serving natively instead)");
                build_server(false, cfg)?
            }
        }
    } else {
        build_server(false, cfg)?
    };
    for (name, entry) in server.registry().iter() {
        println!(
            "registered '{name}': batches {:?}, scheduler {}",
            entry.batch_sizes,
            if entry.plan_costs.is_empty() { "policy fallback" } else { "planner cost model" },
        );
    }
    println!();

    // interleaved trace: both variants loaded at once, each request with
    // a generous deadline and top-1 attached
    let mut rng = Rng::new(2024);
    let mut inflight = Vec::new();
    for _ in 0..requests {
        for variant in ["dense", "sparse"] {
            let digit = rng.below(10);
            let req = ServeRequest::new(variant, digit_image(digit, &mut rng))
                .deadline_ms(250)
                .topk(1);
            inflight.push((variant, digit, server.submit(req)?));
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }

    let mut correct = [0usize; 2];
    let mut missed = [0usize; 2];
    for (variant, truth, rx) in inflight {
        let slot = if variant == "dense" { 0 } else { 1 };
        let resp = rx.recv()?;
        match resp.outcome {
            Ok(_) => {
                let pred = resp.topk.as_ref().and_then(|t| t.first()).map(|&(i, _)| i);
                if pred == Some(truth) {
                    correct[slot] += 1;
                }
            }
            Err(ServeError::Deadline { .. }) => missed[slot] += 1,
            Err(e) => return Err(e.into()),
        }
    }

    let stats = server.stats();
    let mut p50s = Vec::new();
    for (slot, variant) in ["dense", "sparse"].iter().enumerate() {
        let m = server.metrics(variant).unwrap();
        println!("--- variant: {variant} ---");
        println!(
            "{}accuracy on trace: {}/{} = {:.1}% (deadline misses: {})\n",
            m.report(),
            correct[slot],
            requests,
            100.0 * correct[slot] as f64 / requests as f64,
            missed[slot],
        );
        p50s.push(stats[*variant].latency.as_ref().map(|s| s.p50).unwrap_or(0.0));
    }
    println!(
        "p50 latency dense {:.1} ms vs compressed {:.1} ms",
        p50s[0] / 1e3,
        p50s[1] / 1e3
    );
    server.shutdown()?;
    Ok(())
}
