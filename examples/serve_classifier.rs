//! End-to-end serving driver (DESIGN.md §5 "Serving E2E"): start the
//! coordinator, replay a Poisson request trace of synthetic digit images
//! against the dense AND compressed variants, and report latency
//! percentiles, throughput, batch utilization, and trace accuracy per
//! variant.
//!
//! Serves the AOT artifacts when present (`make artifacts` + real PJRT);
//! otherwise the same coordinator batches over the native-kernel engine
//! through the `Backend` trait — no artifacts directory required. (Native
//! weights are synthetic, so trace accuracy is only meaningful on the
//! trained artifact path.)
//!
//! ```sh
//! cargo run --release --example serve_classifier [-- <requests> <rps>]
//! ```

use anyhow::Result;
use cadnn::api::Engine;
use cadnn::compress::profile::paper_profile;
use cadnn::coordinator::{BatchPolicy, BatcherConfig, Coordinator, CoordinatorConfig};
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::util::rng::Rng;

/// Rasterize the same seven-segment procedural digits as
/// python/compile/datasets.py (one glyph, random offset, light noise) so
/// the served model sees in-distribution images.
fn digit_image(digit: usize, rng: &mut Rng) -> Vec<f32> {
    const SEGS: [(usize, usize, usize, usize); 7] = [
        (0, 2, 1, 11),
        (1, 10, 0, 2),
        (1, 10, 10, 12),
        (9, 11, 1, 11),
        (10, 19, 0, 2),
        (10, 19, 10, 12),
        (18, 20, 1, 11),
    ];
    const ON: [[u8; 7]; 10] = [
        [1, 1, 1, 0, 1, 1, 1],
        [0, 0, 1, 0, 0, 1, 0],
        [1, 0, 1, 1, 1, 0, 1],
        [1, 0, 1, 1, 0, 1, 1],
        [0, 1, 1, 1, 0, 1, 0],
        [1, 1, 0, 1, 0, 1, 1],
        [1, 1, 0, 1, 1, 1, 1],
        [1, 0, 1, 0, 0, 1, 0],
        [1, 1, 1, 1, 1, 1, 1],
        [1, 1, 1, 1, 0, 1, 1],
    ];
    let mut img = vec![0.0f32; 28 * 28];
    let (r0, c0) = (rng.range(0, 8), rng.range(0, 16));
    for (s, &(a, b, c, d)) in SEGS.iter().enumerate() {
        if ON[digit][s] == 1 {
            for r in a..b {
                for cc in c..d {
                    img[(r0 + r) * 28 + (c0 + cc)] = 0.85;
                }
            }
        }
    }
    for v in img.iter_mut() {
        *v = (*v + rng.normal() as f32 * 0.08).clamp(0.0, 1.0);
    }
    img
}

/// Start a coordinator for the variant: AOT artifacts when available,
/// otherwise the native engine behind the same `Backend` trait.
fn start_coordinator(variant: &str) -> Result<Coordinator> {
    let batcher = BatcherConfig {
        max_batch: 8,
        max_wait_us: 2_000,
        policy: BatchPolicy::PadToFit,
    };
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match Coordinator::start(CoordinatorConfig {
            artifacts_dir: "artifacts".into(),
            model: "lenet5".into(),
            variant: variant.into(),
            max_batch: batcher.max_batch,
            max_wait_us: batcher.max_wait_us,
            policy: batcher.policy,
        }) {
            Ok(coord) => return Ok(coord),
            Err(e) => eprintln!("(artifact path failed: {e}; serving natively instead)"),
        }
    }
    let mut builder = Engine::native("lenet5").batch_sizes(&[1, 2, 4, 8]);
    if variant == "sparse" {
        let g = models::build("lenet5", 1).expect("lenet5 exists");
        builder = builder
            .personality(Personality::CadnnSparse)
            .sparsity_profile(paper_profile(&g));
    }
    Coordinator::serve_engine(&builder.build()?, batcher)
}

fn run_variant(
    variant: &str,
    requests: usize,
    rps: f64,
) -> Result<(usize, f64, String)> {
    let coord = start_coordinator(variant)?;
    let mut rng = Rng::new(2024);
    let mut truths = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..requests {
        let digit = rng.below(10);
        truths.push(digit);
        rxs.push(coord.submit(digit_image(digit, &mut rng))?);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }
    let mut correct = 0usize;
    for (rx, truth) in rxs.into_iter().zip(&truths) {
        let resp = rx.recv()?;
        let logits = resp.into_logits()?;
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == *truth {
            correct += 1;
        }
    }
    let m = coord.metrics.lock().unwrap();
    let p50 = m.latency_summary().map(|s| s.p50).unwrap_or(0.0);
    let report = m.report();
    drop(m);
    coord.shutdown()?;
    Ok((correct, p50, report))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let rps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60.0);

    println!(
        "=== serve_classifier: lenet5 dense vs compressed, {requests} reqs @ {rps} req/s ===\n"
    );
    let mut p50s = Vec::new();
    for variant in ["dense", "sparse"] {
        println!("--- variant: {variant} ---");
        let (correct, p50, report) = run_variant(variant, requests, rps)?;
        println!(
            "{report}accuracy on trace: {}/{} = {:.1}%\n",
            correct,
            requests,
            100.0 * correct as f64 / requests as f64
        );
        p50s.push(p50);
    }
    println!(
        "p50 latency dense {:.1} ms vs compressed {:.1} ms",
        p50s[0] / 1e3,
        p50s[1] / 1e3
    );
    Ok(())
}
