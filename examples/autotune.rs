//! Optimization-parameter selection demo (paper §4.3): tune tile/unroll
//! configurations for ResNet-50's GEMM shapes on the real blocked-GEMM
//! kernel; print default-vs-tuned and the pruned-space statistics.
//!
//! ```sh
//! cargo run --release --example autotune [-- <model>]
//! ```

use anyhow::{anyhow, Result};
use cadnn::api::Engine;
use cadnn::bench::print_table;
use cadnn::passes::layout;
use cadnn::tuner;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet50".into());
    // the engine's native instance holds the CADNN-lowered graph
    let engine = Engine::native(&model).build()?;
    let inst = engine
        .native_backend()
        .and_then(|b| b.instance(1))
        .ok_or_else(|| anyhow!("no native batch-1 instance for {model}"))?;
    let plan = layout::plan(&inst.graph);

    // dedupe GEMM shapes, largest first, cap the demo at 8 shapes
    let mut shapes: Vec<(usize, usize, usize)> = plan
        .per_node
        .values()
        .map(|i| (i.gemm_m.min(3136), i.gemm_k, i.gemm_n))
        .collect();
    shapes.sort();
    shapes.dedup();
    shapes.sort_by_key(|&(m, k, n)| std::cmp::Reverse(m * k * n));
    shapes.truncate(8);

    println!("autotuning {} GEMM shapes from {model} (cache budget 2 MiB)\n", shapes.len());
    let mut rows = Vec::new();
    let mut total_speedup = 1.0f64;
    for (m, k, n) in &shapes {
        let r = tuner::tune(*m, *k, *n, 2 << 20, 7);
        total_speedup *= r.speedup_vs_default();
        rows.push(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.0}", r.default_us),
            format!("{:.0}", r.best_us),
            format!("{:.2}x", r.speedup_vs_default()),
            format!("mc{} nc{} kc{} u{}", r.best.mc, r.best.nc, r.best.kc, r.best.unroll),
            format!("{}/{}", r.evaluated, r.evaluated + r.pruned),
        ]);
    }
    print_table(
        &["shape MxKxN", "default us", "tuned us", "speedup", "best config", "evals/space"],
        &rows,
    );
    let gm = total_speedup.powf(1.0 / shapes.len().max(1) as f64);
    println!("\ngeometric-mean tuned speedup: {gm:.2}x (feeds Figure 2's CADNN-vs-TVM gap)");
    Ok(())
}
