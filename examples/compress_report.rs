//! Regenerate Table 2 + the §3 compression claims, cross-checking the
//! python accounting (artifacts/compress_report.json, if present)
//! against the independent Rust model/profile accounting.
//!
//! ```sh
//! cargo run --release --example compress_report
//! ```

use anyhow::{anyhow, Result};
use cadnn::bench::{print_table, table2};
use cadnn::compress::profile::paper_profile;
use cadnn::compress::size;
use cadnn::models;
use cadnn::util::json::Json;

fn main() -> Result<()> {
    println!("== Table 2 ==\n");
    let rows: Vec<Vec<String>> = table2::table2()
        .iter()
        .map(|r| {
            vec![
                r.model.to_string(),
                format!("{:.1}", r.size_mb),
                format!("{:.1}", r.paper_size_mb),
                format!("{:.1}/{:.1}", r.top1, r.top5),
                format!("{}", r.weight_layers),
                format!("{}", r.compute_layers),
                format!("{}", r.paper_layers),
            ]
        })
        .collect();
    print_table(
        &["model", "size MB", "paper MB", "top1/top5 (quoted)", "w-layers", "c-layers", "paper"],
        &rows,
    );

    println!("\n== §3 weight-pruning claims (accounting on exact architectures) ==\n");
    let mut rows = Vec::new();
    for (name, claim) in [
        ("lenet5", 348.0),
        ("alexnet", 36.0),
        ("vgg16", 34.0),
        ("resnet18", 8.0),
        ("resnet50", 9.2),
    ] {
        let g = models::build(name, 1).unwrap();
        let r = size::report(&g, &paper_profile(&g));
        rows.push(vec![
            name.to_string(),
            format!("{}", r.weights),
            format!("{}", r.nnz),
            format!("{:.1}x", r.compression_rate),
            format!("{claim}x"),
            format!("{:.0}x", r.storage_reduction_no_idx()),
        ]);
    }
    print_table(
        &["model", "weights", "nnz", "rate", "paper", "4bit storage (no idx)"],
        &rows,
    );

    // cross-check vs the python accounting if the report exists
    if let Ok(text) = std::fs::read_to_string("artifacts/compress_report.json") {
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        println!("\n== cross-check vs python (artifacts/compress_report.json) ==\n");
        if let Some(acc) = j.get("accounted") {
            for name in ["alexnet", "vgg16"] {
                if let Some(a) = acc.get(name) {
                    let py_total = a.get("total_weights").and_then(|v| v.as_usize()).unwrap_or(0);
                    let py_rate = a.get("rate").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let g = models::build(name, 1).unwrap();
                    let r = size::report(&g, &paper_profile(&g));
                    let total_match = py_total == r.weights;
                    let rate_match = (py_rate - r.compression_rate).abs() < 1.0;
                    println!(
                        "{name}: weights {} (python {}) {}  rate {:.1} (python {:.1}) {}",
                        r.weights,
                        py_total,
                        if total_match { "OK" } else { "MISMATCH" },
                        r.compression_rate,
                        py_rate,
                        if rate_match { "OK" } else { "MISMATCH" },
                    );
                    if !total_match || !rate_match {
                        return Err(anyhow!("{name}: rust/python accounting disagrees"));
                    }
                }
            }
        }
        if let Some(l) = j.get("measured").and_then(|m| m.get("lenet5")) {
            println!("\nmeasured lenet5 (python ADMM on synthetic digits):");
            for key in [
                "dense_acc", "pruned_acc", "pruned_rate", "quant_acc", "quant_rate",
                "storage_reduction_no_idx",
            ] {
                if let Some(v) = l.get(key).and_then(|v| v.as_f64()) {
                    println!("  {key:28} = {v}");
                }
            }
        }
    } else {
        println!("\n(run `make compress-report` for the measured python ADMM results)");
    }
    Ok(())
}
