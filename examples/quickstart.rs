//! Quickstart: load the AOT-compiled LeNet-5 artifact via PJRT, classify
//! one image from the golden set, print the prediction and latency.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{anyhow, Result};
use cadnn::runtime::Runtime;
use cadnn::util::json::Json;
use cadnn::util::Stopwatch;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    rt.load("lenet5", "dense")?;
    let model = rt
        .get("lenet5", "dense", 1)
        .ok_or_else(|| anyhow!("batch-1 lenet5 not in manifest"))?;
    println!(
        "loaded lenet5/dense b1 ({} classes, trained acc {:.1}%)",
        model.entry.classes,
        model.entry.accuracy * 100.0
    );

    // One image from the golden set (written by aot.py alongside the HLO).
    let golden_text = std::fs::read_to_string(format!("{dir}/golden/lenet5_dense.json"))?;
    let golden = Json::parse(&golden_text).map_err(|e| anyhow!("{e}"))?;
    let input = golden.get("input").and_then(|v| v.as_f32_vec()).unwrap();
    let labels = golden.get("labels").and_then(|v| v.as_usize_vec()).unwrap();
    let per_image = 28 * 28;

    // warmup + timed single-image inference
    let _ = model.run(&input[..per_image])?;
    let sw = Stopwatch::new();
    let logits = model.run(&input[..per_image])?;
    let us = sw.elapsed_us();

    let pred = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!("prediction: {pred} (label: {}) in {:.2} ms", labels[0], us / 1e3);
    println!("logits: {logits:?}");
    Ok(())
}
