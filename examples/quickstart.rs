//! Quickstart for the unified API: build an `Engine`, open a `Session`,
//! classify one image, print the prediction and latency.
//!
//! Prefers the AOT-compiled PJRT artifact (`make artifacts` + the real
//! `xla` binding); transparently falls back to the native-kernel engine
//! when artifacts are unavailable, so it runs anywhere.
//!
//! ```sh
//! cargo run --release --example quickstart [-- <artifacts_dir>]
//! ```

use anyhow::{anyhow, Result};
use cadnn::api::Engine;
use cadnn::util::json::Json;
use cadnn::util::Stopwatch;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // one builder flow for both execution worlds
    let (engine, golden) = match Engine::artifacts(&dir, "lenet5", "dense").build() {
        Ok(engine) => {
            println!("engine: {} (AOT artifact)", engine.name());
            (engine, Some(format!("{dir}/golden/lenet5_dense.json")))
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); falling back to native kernels");
            let engine = Engine::native("lenet5").build()?;
            println!("engine: {} (native)", engine.name());
            (engine, None)
        }
    };
    println!(
        "input {:?} -> {} classes, batches {:?}",
        engine.input_shape(),
        engine.classes(),
        engine.batch_sizes()
    );

    // image: golden set when artifacts exist, a deterministic ramp otherwise
    let per_image = engine.input_len();
    let (image, label): (Vec<f32>, Option<usize>) = match &golden {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let g = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
            let input = g
                .get("input")
                .and_then(|v| v.as_f32_vec())
                .ok_or_else(|| anyhow!("golden file missing input"))?;
            let labels = g
                .get("labels")
                .and_then(|v| v.as_usize_vec())
                .ok_or_else(|| anyhow!("golden file missing labels"))?;
            (input[..per_image].to_vec(), Some(labels[0]))
        }
        None => ((0..per_image).map(|i| ((i % 17) as f32) / 17.0).collect(), None),
    };

    // warmup + timed single-image inference; the session reuses buffers
    let mut session = engine.session();
    let _ = session.run(&image)?;
    let sw = Stopwatch::new();
    let logits = session.run(&image)?;
    let us = sw.elapsed_us();

    let pred = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    match label {
        Some(l) => println!("prediction: {pred} (label: {l}) in {:.2} ms", us / 1e3),
        None => println!("prediction: {pred} in {:.2} ms", us / 1e3),
    }
    println!("logits: {logits:?}");
    Ok(())
}
