//! Regenerate Figure 2 (thin wrapper over the shared harness; identical
//! to `cadnn figure2`).
//!
//! ```sh
//! cargo run --release --example figure2 [-- --measured]
//! ```

use cadnn::bench::{figure2, print_table};
use cadnn::costmodel::calibrate;
use cadnn::models;

fn main() {
    let measured = std::env::args().any(|a| a == "--measured");
    let calib = if measured {
        eprintln!("calibrating host kernels...");
        calibrate::measure_host()
    } else {
        calibrate::CalibrationTable::nominal()
    };
    if calib.measured {
        eprintln!(
            "host peak {:.1} GFLOPS, ratios: naive {:.3} blocked {:.3} csr {:.3}",
            calib.host_peak_gflops,
            calib.direct_conv.compute,
            calib.gemm.compute,
            calib.csr_gemm.compute
        );
    }
    let rows = figure2::figure2(&calib, 1.25);
    let mut table = Vec::new();
    for m in models::EVAL_MODELS {
        let mut row = vec![m.to_string()];
        for s in figure2::SERIES {
            row.push(
                rows.iter()
                    .find(|r| r.model == m && r.series == s)
                    .map(|r| format!("{:.1}", r.latency_ms))
                    .unwrap_or_default(),
            );
        }
        table.push(row);
    }
    let mut headers = vec!["model"];
    headers.extend(figure2::SERIES);
    println!("Figure 2 — inference latency (ms) on the Table-1 device model\n");
    print_table(&headers, &table);
    let h = figure2::headline(&rows);
    println!(
        "\nheadline: resnet50 SC {:.1} ms / SG {:.1} ms (paper 26 / 21); \
         speedup vs TFLite up to {:.1}x (paper 8.8x), vs TVM up to {:.1}x (paper 6.4x)",
        h.resnet50_sc_ms, h.resnet50_sg_ms, h.max_speedup_vs_tflite, h.max_speedup_vs_tvm
    );
}
