//! Sparse-format planner walkthrough: build one compressed model under
//! every `FormatPolicy`, show what the planner chose per layer, and time
//! a few inferences per policy so the format/latency tradeoff is visible.
//!
//! ```sh
//! cargo run --release --example sparse_formats [-- <model>]
//! ```

use anyhow::{anyhow, Result};
use cadnn::api::Engine;
use cadnn::compress::profile::paper_profile;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::planner::FormatPolicy;
use cadnn::util::Stopwatch;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "lenet5".into());
    let g = models::build(&model, 1).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let profile = paper_profile(&g);

    for policy in
        [FormatPolicy::Auto, FormatPolicy::Csr, FormatPolicy::Bsr, FormatPolicy::Pattern]
    {
        let engine = Engine::native(&model)
            .personality(Personality::CadnnSparse)
            .sparsity_profile(profile.clone())
            .sparse_format(policy)
            .build()?;
        let inst = engine
            .native_backend()
            .and_then(|b| b.instance(1))
            .ok_or_else(|| anyhow!("native instance missing"))?;
        let counts: Vec<String> = inst
            .plan
            .format_counts()
            .iter()
            .map(|(f, c)| format!("{f} x{c}"))
            .collect();
        println!("policy {policy:?}: {}", counts.join(", "));
        for (name, lp) in &inst.plan.layers {
            println!(
                "  {name:<12} {:<7} reorder={} cutover={}",
                lp.format.label(),
                lp.reorder,
                lp.parallel_cutover
            );
        }

        // a few timed runs — sessions reuse buffers, so this is steady state
        let image: Vec<f32> = (0..engine.input_len()).map(|i| ((i % 17) as f32) / 17.0).collect();
        let mut session = engine.session();
        let _ = session.run(&image)?;
        let sw = Stopwatch::new();
        let iters = 10;
        for _ in 0..iters {
            let _ = session.run(&image)?;
        }
        println!("  -> {:.2} ms/inference\n", sw.elapsed_us() / iters as f64 / 1e3);
    }
    Ok(())
}
