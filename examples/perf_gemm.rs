//! §Perf probe used during the performance pass (EXPERIMENTS.md §Perf):
//! measures naive vs blocked GEMM across tile variants on a
//! ResNet-50-representative shape. Kept as the reproducible harness for
//! re-running the optimization log.
use cadnn::kernels::gemm::{gemm_blocked, gemm_naive};
use cadnn::kernels::Epilogue;
use cadnn::passes::layout::TileConfig;
use cadnn::util::rng::Rng;
use cadnn::util::stats;

fn main() {
    let (m, k, n) = (784usize, 576usize, 128usize);
    let mut rng = Rng::new(1);
    let a: Vec<f32> = (0..m*k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k*n).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m*n];
    let flops = 2.0 * (m*k*n) as f64;
    let t = stats::Summary::from(&stats::measure_adaptive_us(300_000.0, 10, || gemm_naive(&a,&b,&mut c,m,k,n))).unwrap().p50;
    println!("naive: {:.0}us {:.1} GF/s", t, flops/t/1e3);
    for (mc,nc,kc,u) in [(64,128,256,8),(64,128,192,8),(64,128,576,8),(128,256,256,8),(64,64,256,8)] {
        let tile = TileConfig{mc,nc,kc,unroll:u};
        let t = stats::Summary::from(&stats::measure_adaptive_us(300_000.0, 10, || gemm_blocked(&a,&b,&mut c,m,k,n,&tile,&Epilogue::None))).unwrap().p50;
        println!("blocked mc{mc} nc{nc} kc{kc} u{u}: {:.0}us {:.1} GF/s", t, flops/t/1e3);
    }
}
