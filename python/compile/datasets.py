"""Synthetic datasets (DESIGN.md §2 substitution for MNIST/ImageNet).

The paper measures accuracy on MNIST-class (LeNet-5) and ImageNet-class
tasks. Neither dataset ships in this environment, so:

- ``synthetic_digits`` renders a *procedural* 10-class digit task:
  seven-segment glyphs rasterized at 28x28 with random translation, stroke
  jitter and pixel noise. It is learnable-but-not-trivial, which is what
  the pruning-accuracy experiments need (a task where damage from
  over-pruning is measurable).
- ``seeded_images`` produces deterministic natural-image-statistics tensors
  (low-frequency mixture) for throughput/serving workloads where only the
  shape and byte volume matter.
"""

from __future__ import annotations

import numpy as np

# Seven-segment encodings for digits 0-9; segments:
#   0: top, 1: top-left, 2: top-right, 3: middle, 4: bottom-left,
#   5: bottom-right, 6: bottom.
_SEGMENTS = {
    0: (1, 1, 1, 0, 1, 1, 1),
    1: (0, 0, 1, 0, 0, 1, 0),
    2: (1, 0, 1, 1, 1, 0, 1),
    3: (1, 0, 1, 1, 0, 1, 1),
    4: (0, 1, 1, 1, 0, 1, 0),
    5: (1, 1, 0, 1, 0, 1, 1),
    6: (1, 1, 0, 1, 1, 1, 1),
    7: (1, 0, 1, 0, 0, 1, 0),
    8: (1, 1, 1, 1, 1, 1, 1),
    9: (1, 1, 1, 1, 0, 1, 1),
}

# Segment geometry on a 20x12 glyph box: (row0, row1, col0, col1).
_GEOM = {
    0: (0, 2, 1, 11),
    1: (1, 10, 0, 2),
    2: (1, 10, 10, 12),
    3: (9, 11, 1, 11),
    4: (10, 19, 0, 2),
    5: (10, 19, 10, 12),
    6: (18, 20, 1, 11),
}


def _glyph(digit: int) -> np.ndarray:
    g = np.zeros((20, 12), np.float32)
    for seg, on in enumerate(_SEGMENTS[digit]):
        if on:
            r0, r1, c0, c1 = _GEOM[seg]
            g[r0:r1, c0:c1] = 1.0
    return g


_GLYPHS = [_glyph(d) for d in range(10)]


def synthetic_digits(n: int, seed: int = 0, size: int = 28):
    """Return (images, labels): images (n, size, size, 1) f32 in [0,1],
    labels (n,) int32."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, size, size, 1), np.float32)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    max_r = size - 20
    max_c = size - 12
    for i, d in enumerate(labels):
        canvas = np.zeros((size, size), np.float32)
        r = rng.integers(0, max_r + 1)
        c = rng.integers(0, max_c + 1)
        glyph = _GLYPHS[d] * rng.uniform(0.7, 1.0)
        # Stroke jitter: randomly erode a few pixels.
        jitter = (rng.random(glyph.shape) > 0.06).astype(np.float32)
        canvas[r : r + 20, c : c + 12] = glyph * jitter
        canvas += rng.normal(0.0, 0.08, canvas.shape).astype(np.float32)
        imgs[i, :, :, 0] = np.clip(canvas, 0.0, 1.0)
    return imgs, labels


def seeded_images(n: int, h: int, w: int, c: int, seed: int = 0) -> np.ndarray:
    """Deterministic low-frequency image-like tensors, (n,h,w,c) f32."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    imgs = np.zeros((n, h, w, c), np.float32)
    for i in range(n):
        acc = np.zeros((h, w), np.float32)
        for _ in range(4):
            fx, fy = rng.uniform(0.02, 0.3, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            acc += rng.uniform(0.2, 1.0) * np.sin(fx * xx + px) * np.cos(fy * yy + py)
        acc = (acc - acc.min()) / max(float(np.ptp(acc)), 1e-6)
        for ch in range(c):
            imgs[i, :, :, ch] = np.clip(acc + rng.normal(0, 0.05, (h, w)), 0, 1)
    return imgs
