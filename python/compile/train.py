"""Minimal trainer used by the ADMM compression experiments.

SGD with momentum over softmax cross-entropy. Deliberately tiny: the
compression experiments (compress_run.py) are the consumer, and they run
on the synthetic digit task at LeNet-5 scale on CPU.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(apply_fn, params, x, y, batch: int = 256) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = apply_fn(params, jnp.asarray(x[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y[i : i + batch])))
    return correct / len(x)


def _tree_sgd(params, grads, vel, lr, momentum, mask=None):
    new_p, new_v = {}, {}
    for k, p in params.items():
        if isinstance(p, dict):
            sub_m = mask.get(k) if isinstance(mask, dict) else None
            new_p[k], new_v[k] = _tree_sgd(p, grads[k], vel[k], lr, momentum, sub_m)
        else:
            g = grads[k]
            v = momentum * vel[k] - lr * g
            # Masked retraining (paper §3): updates are masked, so entries
            # outside the support (already 0 after projection) stay 0, and
            # an all-zero mask freezes a layer at its current (projected)
            # values — used by the quantization-recovery phase.
            m = mask.get(k) if isinstance(mask, dict) else None
            if m is not None:
                v = v * m
            new_p[k], new_v[k] = p + v, v
    return new_p, new_v


def _tree_zeros(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def train(
    apply_fn: Callable,
    params,
    x,
    y,
    *,
    epochs: int = 5,
    batch: int = 64,
    lr: float = 0.01,
    momentum: float = 0.9,
    seed: int = 0,
    loss_extra: Optional[Callable] = None,
    weight_masks: Optional[Dict[str, jnp.ndarray]] = None,
    log: Optional[Callable[[str], None]] = None,
):
    """Train ``params``; returns (params, loss_history).

    ``loss_extra(params)`` adds a regularizer (the ADMM proximal term).
    ``weight_masks`` maps layer name -> {0,1} mask over that layer's "w"
    for masked (fixed-support) retraining.
    """

    def loss_fn(p, xb, yb):
        loss = cross_entropy(apply_fn(p, xb), yb)
        if loss_extra is not None:
            loss = loss + loss_extra(p)
        return loss

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    vel = _tree_zeros(params)
    rng = np.random.default_rng(seed)
    history = []
    n = len(x)
    mask_tree = (
        {k: {"w": m} for k, m in weight_masks.items()} if weight_masks else None
    )
    for ep in range(epochs):
        order = rng.permutation(n)
        ep_loss, steps = 0.0, 0
        for i in range(0, n, batch):
            idx = order[i : i + batch]
            xb = jnp.asarray(x[idx])
            yb = jnp.asarray(y[idx])
            loss, grads = grad_fn(params, xb, yb)
            params, vel = _tree_sgd(
                params, grads, vel, lr, momentum,
                mask_tree if mask_tree else None,
            )
            ep_loss += float(loss)
            steps += 1
        history.append(ep_loss / max(steps, 1))
        if log:
            log(f"epoch {ep}: loss={history[-1]:.4f}")
    return params, history
