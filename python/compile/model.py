"""Layer-2: the JAX model zoo.

Every model is a *functional* (params, x) -> logits pair with two
backends sharing identical math:

- ``backend="ref"``    — pure jnp/lax ops (differentiable; used by the
  trainer and the ADMM compressor).
- ``backend="pallas"`` — the Layer-1 Pallas kernels (fused conv+bn+relu,
  1x1->GEMM, block-sparse GEMM). This is what ``aot.py`` lowers into the
  HLO artifacts the Rust runtime serves.

Backend equivalence (pallas fwd == ref fwd) is itself a pytest property —
it is the L2 analogue of the paper's claim that the architecture-aware
transformations are semantics-preserving.

Artifacts bake the (possibly compressed) weights in as HLO constants:
the unit of deployment is a *model-specific compiled binary*, exactly
like the paper's compiler-generated mobile code.

The full-size ImageNet architectures (ResNet-50, MobileNet-V1/V2,
Inception-V3, plus the §3 pruning subjects) live on the Rust side as IR
graphs for work/latency accounting; the models here are the *executed*
subjects (LeNet-5 full-size, plus scaled "tiny" residual/depthwise models
exercising the same layer vocabulary) — DESIGN.md §2.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    conv2d_fused,
    depthwise_fused,
    gemm,
    gemm_bn_relu,
)
from .kernels import ref
from .kernels.conv_fused import conv2d_sparse_fused
from .kernels.sparse_gemm import sparse_gemm_bn_relu, tile_mask_from_weights

Params = Dict[str, Any]

# Tile granularity used for block-sparse execution of compressed layers.
# 16x16 keeps tiny-model masks meaningful; on a real TPU these would be
# 128x128 MXU tiles (DESIGN.md §Hardware-Adaptation).
SPARSE_BK = 16
SPARSE_BN = 16


# --------------------------------------------------------------- layers


def _fold_bn(gamma, beta, mean, var, eps=1e-5):
    """Inference-time BN folding -> per-channel affine (scale, shift)."""
    scale = gamma / jnp.sqrt(var + eps)
    return scale, beta - mean * scale


def conv_block(x, p, *, stride, padding, relu=True, backend="ref", mask=None):
    """Conv + folded-BN + optional ReLU. ``p`` holds w/(gamma,beta,mean,var).

    When ``mask`` is given (a weight-tile mask from the compressor) the
    pallas backend dispatches to the block-sparse fused kernel.
    """
    scale, shift = _fold_bn(p["gamma"], p["beta"], p["mean"], p["var"])
    if backend == "pallas":
        if mask is not None:
            return conv2d_sparse_fused(
                x, p["w"], mask, scale, shift, stride=stride, padding=padding,
                bk=SPARSE_BK, bn=SPARSE_BN,
            )
        return conv2d_fused(
            x, p["w"], scale, shift, stride=stride, padding=padding, relu=relu
        )
    out = ref.conv2d(x, p["w"], stride, padding)
    out = out * scale.reshape(1, 1, 1, -1) + shift.reshape(1, 1, 1, -1)
    return jnp.maximum(out, 0.0) if relu else out


def dw_block(x, p, *, stride, padding, backend="ref"):
    """DepthwiseConv + folded-BN + ReLU (the MobileNet fusion target)."""
    scale, shift = _fold_bn(p["gamma"], p["beta"], p["mean"], p["var"])
    if backend == "pallas":
        return depthwise_fused(x, p["w"], scale, shift, stride=stride, padding=padding)
    out = ref.depthwise(x, p["w"], stride, padding)
    out = out * scale.reshape(1, 1, 1, -1) + shift.reshape(1, 1, 1, -1)
    return jnp.maximum(out, 0.0)


def fc_block(x, p, *, relu=True, backend="ref", mask=None):
    """Fully connected + bias (+ ReLU): expressed as the same fused GEMM
    epilogue with scale=1."""
    n_out = p["w"].shape[1]
    ones = jnp.ones((n_out,), jnp.float32)
    if backend == "pallas":
        if mask is not None:
            return sparse_gemm_bn_relu(
                x, p["w"], mask, ones, p["b"], bk=SPARSE_BK, bn=SPARSE_BN
            )
        if relu:
            return gemm_bn_relu(x, p["w"], ones, p["b"])
        return gemm(x, p["w"]) + p["b"].reshape(1, -1)
    out = x @ p["w"] + p["b"].reshape(1, -1)
    return jnp.maximum(out, 0.0) if relu else out


# ------------------------------------------------------- initializers


def _he(rng, shape):
    fan_in = int(np.prod(shape[:-1]))
    return jnp.asarray(
        rng.normal(0.0, np.sqrt(2.0 / max(fan_in, 1)), size=shape), jnp.float32
    )


def _bn_init(c):
    return dict(
        gamma=jnp.ones((c,), jnp.float32),
        beta=jnp.zeros((c,), jnp.float32),
        mean=jnp.zeros((c,), jnp.float32),
        var=jnp.ones((c,), jnp.float32),
    )


def _conv_p(rng, kh, kw, cin, cout):
    return dict(w=_he(rng, (kh, kw, cin, cout)), **_bn_init(cout))


def _dw_p(rng, kh, kw, c):
    return dict(w=_he(rng, (kh, kw, c)), **_bn_init(c))


def _fc_p(rng, nin, nout):
    return dict(w=_he(rng, (nin, nout)), b=jnp.zeros((nout,), jnp.float32))


# --------------------------------------------------------------- LeNet-5


def lenet5_init(seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    return {
        "c1": _conv_p(rng, 5, 5, 1, 6),
        "c2": _conv_p(rng, 5, 5, 6, 16),
        "f1": _fc_p(rng, 16 * 5 * 5, 120),
        "f2": _fc_p(rng, 120, 84),
        "f3": _fc_p(rng, 84, 10),
    }


def lenet5_apply(p: Params, x, *, backend="ref", masks=None) -> jnp.ndarray:
    """LeNet-5 (28x28x1 -> 10). ``masks`` maps layer name -> tile mask for
    compressed execution (pallas backend only)."""
    m = masks or {}
    x = conv_block(x, p["c1"], stride=1, padding=2, backend=backend, mask=m.get("c1"))
    x = ref.maxpool(x)  # pooling has no weights; plain lax reduce_window
    x = conv_block(x, p["c2"], stride=1, padding=0, backend=backend, mask=m.get("c2"))
    x = ref.maxpool(x)
    x = x.reshape(x.shape[0], -1)
    x = fc_block(x, p["f1"], backend=backend, mask=m.get("f1"))
    x = fc_block(x, p["f2"], backend=backend, mask=m.get("f2"))
    return fc_block(x, p["f3"], relu=False, backend=backend)


# The layers ADMM compresses, with their weight-matrix views.
LENET5_PRUNABLE = ("c1", "c2", "f1", "f2")


# ----------------------------------------------------------- TinyResNet


def tinyresnet_init(seed: int = 0, width: int = 8) -> Params:
    rng = np.random.default_rng(seed)
    w = width
    p: Params = {"stem": _conv_p(rng, 3, 3, 3, w)}
    cin = w
    for s, cout in enumerate((w, 2 * w, 4 * w)):
        for b in range(2):
            pre = f"s{s}b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            p[f"{pre}_c1"] = _conv_p(rng, 3, 3, cin, cout)
            p[f"{pre}_c2"] = _conv_p(rng, 3, 3, cout, cout)
            if stride != 1 or cin != cout:
                p[f"{pre}_sc"] = _conv_p(rng, 1, 1, cin, cout)
            cin = cout
    p["fc"] = _fc_p(rng, cin, 10)
    return p


def tinyresnet_apply(p: Params, x, *, backend="ref", masks=None) -> jnp.ndarray:
    """Residual CNN for 32x32x3 -> 10 (ResNet-18-shaped, width-scaled)."""
    m = masks or {}
    x = conv_block(x, p["stem"], stride=1, padding=1, backend=backend, mask=m.get("stem"))
    width = p["stem"]["w"].shape[-1]
    for s in range(3):
        for b in range(2):
            pre = f"s{s}b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            idn = x
            out = conv_block(
                x, p[f"{pre}_c1"], stride=stride, padding=1, backend=backend,
                mask=m.get(f"{pre}_c1"),
            )
            out = conv_block(
                out, p[f"{pre}_c2"], stride=1, padding=1, relu=False,
                backend=backend, mask=None if backend == "ref" else None,
            )
            if f"{pre}_sc" in p:
                idn = conv_block(
                    idn, p[f"{pre}_sc"], stride=stride, padding=0, relu=False,
                    backend=backend,
                )
            x = jnp.maximum(out + idn, 0.0)
    x = ref.avgpool_global(x)
    return fc_block(x, p["fc"], relu=False, backend=backend)


TINYRESNET_PRUNABLE = tuple(
    [f"s{s}b{b}_c1" for s in range(3) for b in range(2)]
    + [f"s{s}b{b}_c2" for s in range(3) for b in range(2)]
)


# -------------------------------------------------------- TinyMobileNet


def tinymobilenet_init(seed: int = 0, width: int = 8) -> Params:
    rng = np.random.default_rng(seed)
    w = width
    chans = [(w, 2 * w, 1), (2 * w, 2 * w, 1), (2 * w, 4 * w, 2), (4 * w, 4 * w, 1)]
    p: Params = {"stem": _conv_p(rng, 3, 3, 3, w)}
    for i, (cin, cout, _s) in enumerate(chans):
        p[f"b{i}_dw"] = _dw_p(rng, 3, 3, cin)
        p[f"b{i}_pw"] = _conv_p(rng, 1, 1, cin, cout)
    p["fc"] = _fc_p(rng, chans[-1][1], 10)
    return p


def tinymobilenet_apply(p: Params, x, *, backend="ref", masks=None) -> jnp.ndarray:
    """MobileNet-V1-shaped depthwise-separable CNN, 32x32x3 -> 10.

    The pointwise (1x1) convs take the paper's 1x1->GEMM path inside
    ``conv_block`` and are the block-sparse targets when compressed."""
    m = masks or {}
    x = conv_block(x, p["stem"], stride=2, padding=1, backend=backend)
    strides = [1, 1, 2, 1]
    for i, s in enumerate(strides):
        x = dw_block(x, p[f"b{i}_dw"], stride=s, padding=1, backend=backend)
        x = conv_block(
            x, p[f"b{i}_pw"], stride=1, padding=0, backend=backend,
            mask=m.get(f"b{i}_pw"),
        )
    x = ref.avgpool_global(x)
    return fc_block(x, p["fc"], relu=False, backend=backend)


TINYMOBILENET_PRUNABLE = tuple(f"b{i}_pw" for i in range(4))


# -------------------------------------------------------------- registry


def weight_matrix(p_layer: Params) -> jnp.ndarray:
    """View a layer's weights as the (K, N) matrix the GEMM kernels see."""
    w = p_layer["w"]
    if w.ndim == 4:  # conv HWIO -> (kh*kw*cin, cout)
        return w.reshape(-1, w.shape[-1])
    return w  # fc already (nin, nout)


def masks_from_params(params: Params, prunable) -> Dict[str, jnp.ndarray]:
    """Derive per-layer weight-tile masks from (already pruned) params."""
    out = {}
    for name in prunable:
        wm = weight_matrix(params[name])
        out[name] = tile_mask_from_weights(wm, SPARSE_BK, SPARSE_BN)
    return out


MODELS = {
    "lenet5": dict(
        init=lenet5_init,
        apply=lenet5_apply,
        input_shape=(28, 28, 1),
        classes=10,
        prunable=LENET5_PRUNABLE,
    ),
    "tinyresnet": dict(
        init=tinyresnet_init,
        apply=tinyresnet_apply,
        input_shape=(32, 32, 3),
        classes=10,
        prunable=TINYRESNET_PRUNABLE,
    ),
    "tinymobilenet": dict(
        init=tinymobilenet_init,
        apply=tinymobilenet_apply,
        input_shape=(32, 32, 3),
        classes=10,
        prunable=TINYMOBILENET_PRUNABLE,
    ),
}
