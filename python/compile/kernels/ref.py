"""Pure-jnp oracles for every Layer-1 kernel.

These are the CORE correctness signal: pytest asserts each Pallas kernel
allclose against these on hypothesis-generated shapes. Nothing here is
ever lowered into artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def gemm(x, y):
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))


def gemm_bn_relu(x, y, scale, shift):
    return jnp.maximum(gemm(x, y) * scale.reshape(1, -1) + shift.reshape(1, -1), 0.0)


def expand_tile_mask(mask, k, n, bk, bn):
    """(K/bk, N/bn) tile mask -> (K, N) element mask (cropped)."""
    e = jnp.repeat(jnp.repeat(mask, bk, axis=0), bn, axis=1)
    return e[:k, :n].astype(jnp.float32)


def sparse_gemm(x, y, mask, bk, bn):
    k, n = y.shape
    return gemm(x, y * expand_tile_mask(mask, k, n, bk, bn))


def sparse_gemm_bn_relu(x, y, mask, scale, shift, bk, bn):
    k, n = y.shape
    return gemm_bn_relu(x, y * expand_tile_mask(mask, k, n, bk, bn), scale, shift)


def conv2d(x, w, stride=1, padding=0):
    """NHWC x HWIO -> NHWC, matching conv2d_fused's geometry."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_fused(x, w, scale, shift, stride=1, padding=0, relu=True):
    out = conv2d(x, w, stride, padding)
    out = out * scale.reshape(1, 1, 1, -1) + shift.reshape(1, 1, 1, -1)
    return jnp.maximum(out, 0.0) if relu else out


def depthwise(x, w, stride=1, padding=0):
    """NHWC, w: (kh, kw, C). Depthwise = grouped conv with groups=C."""
    c = x.shape[-1]
    wf = w[:, :, None, :]  # (kh, kw, 1, C): HWIO with I=1, O=C groups
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        wf.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def depthwise_fused(x, w, scale, shift, stride=1, padding=0):
    out = depthwise(x, w, stride, padding)
    out = out * scale.reshape(1, 1, 1, -1) + shift.reshape(1, 1, 1, -1)
    return jnp.maximum(out, 0.0)


def maxpool(x, k=2, stride=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))
