"""Shared helpers for the Pallas kernels: padding, block-size selection.

The paper (§4, "memory layout transformation") pads and aligns filter
layouts so tiles divide evenly; we do the same at the kernel boundary so
the Pallas grids never see ragged blocks.
"""

from __future__ import annotations

import jax.numpy as jnp

# MXU-friendly default tiles (128x128 systolic array). On the interpret
# path these only shape the grid; on a real TPU they are the VMEM tiles.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``x``."""
    return ((x + m - 1) // m) * m


def pick_block(dim: int, preferred: int, minimum: int = 8) -> int:
    """Pick a block size for ``dim``: the preferred MXU tile when the
    dimension is large enough, otherwise the smallest power of two >= dim
    (clamped to ``minimum``). Keeps tiny test shapes from exploding into
    mostly-padding grids."""
    if dim >= preferred:
        return preferred
    b = minimum
    while b < dim:
        b *= 2
    return b


def pad2(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    """Zero-pad a rank-2 array so each dim is a multiple of (m0, m1)."""
    p0 = round_up(x.shape[0], m0) - x.shape[0]
    p1 = round_up(x.shape[1], m1) - x.shape[1]
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def pad1(x: jnp.ndarray, m: int) -> jnp.ndarray:
    p = round_up(x.shape[0], m) - x.shape[0]
    if p == 0:
        return x
    return jnp.pad(x, ((0, p),))
