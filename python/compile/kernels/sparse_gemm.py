"""Block-sparse GEMM Pallas kernel — the compressed-model hot path.

TPU adaptation of the paper's non-structured sparsity (DESIGN.md
§Hardware-Adaptation): element-level CSR irregularity does not pay on a
128x128 systolic array, so pruning is expressed at *tile* granularity — a
(K/bk, N/bn) {0,1} mask over weight tiles. Tiles whose mask is zero are
skipped inside the kernel with ``pl.when``, which on a real TPU elides the
MXU work for that grid step; the share of skipped steps equals the tile
sparsity, preserving the paper's "pruned weights are never computed"
property. The Rust (CPU) side keeps element-level CSR, mirroring the
paper's CPU backend where irregular skipping *does* pay.

The weight-tile mask is produced by the ADMM compressor
(python/compile/admm.py) when run with block-granular projection.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BM, DEFAULT_BN, DEFAULT_BK, pad1, pad2, pick_block


def _sparse_gemm_kernel(mask_ref, x_ref, y_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _compute():
        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )


def _sparse_gemm_bn_relu_kernel(
    mask_ref, x_ref, y_ref, scale_ref, shift_ref, o_ref, *, nk: int
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _compute():
        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        o_ref[...] = jnp.maximum(acc * scale_ref[...] + shift_ref[...], 0.0)


def _prep(x, y, bm, bn, bk):
    m, kdim = x.shape
    k2, n = y.shape
    assert kdim == k2, f"inner dims mismatch: {kdim} vs {k2}"
    bm_ = bm or pick_block(m, DEFAULT_BM)
    bn_ = bn or pick_block(n, DEFAULT_BN)
    bk_ = bk or pick_block(kdim, DEFAULT_BK)
    xp = pad2(x.astype(jnp.float32), bm_, bk_)
    yp = pad2(y.astype(jnp.float32), bk_, bn_)
    return xp, yp, bm_, bn_, bk_, m, n


def tile_mask_from_weights(y: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    """Derive the (K/bk, N/bn) tile mask from a (K, N) weight matrix:
    a tile is live iff it contains any non-zero weight."""
    yp = pad2(y, bk, bn)
    kp, np_ = yp.shape
    t = yp.reshape(kp // bk, bk, np_ // bn, bn)
    return (jnp.abs(t).sum(axis=(1, 3)) > 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sparse_gemm(x, y, mask, *, bm=None, bn=None, bk=None):
    """``x @ (y * expand(mask))`` where ``mask`` is the (K/bk, N/bn) weight
    tile mask; zero tiles are skipped, not multiplied.

    x: (M, K), y: (K, N), mask: (ceil(K/bk), ceil(N/bn)) int32.
    """
    xp, yp, bm_, bn_, bk_, m, n = _prep(x, y, bm, bn, bk)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk_
    assert mask.shape == (nk, np_ // bn_), (
        f"mask shape {mask.shape} != {(nk, np_ // bn_)}"
    )
    out = pl.pallas_call(
        functools.partial(_sparse_gemm_kernel, nk=nk),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(mask.astype(jnp.int32), xp, yp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sparse_gemm_bn_relu(x, y, mask, scale, shift, *, bm=None, bn=None, bk=None):
    """Block-sparse GEMM with the fused BN+ReLU epilogue (compressed
    1x1-conv / FC layer in one kernel)."""
    xp, yp, bm_, bn_, bk_, m, n = _prep(x, y, bm, bn, bk)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk_
    assert mask.shape == (nk, np_ // bn_)
    sp = pad1(scale.astype(jnp.float32), bn_).reshape(1, -1)
    hp = pad1(shift.astype(jnp.float32), bn_).reshape(1, -1)
    out = pl.pallas_call(
        functools.partial(_sparse_gemm_bn_relu_kernel, nk=nk),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(mask.astype(jnp.int32), xp, yp, sp, hp)
    return out[:m, :n]
