"""Tiled dense GEMM Pallas kernel, with an optional fused BN+ReLU epilogue.

This is the workhorse the paper's 1x1-conv->matmul transformation targets
(§4 "model computation fusion and transformation"). TPU adaptation: the
threadblock tiling of the mobile GPU version becomes a (M/bm, N/bn, K/bk)
Pallas grid whose BlockSpecs stage MXU-shaped tiles through VMEM; the
epilogue (BatchNorm scale/shift folded to per-column affine, then ReLU)
runs on the VMEM-resident accumulator so the intermediate never touches
HBM — exactly the DRAM-round-trip elimination the paper's fusion buys on
the phone.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BM, DEFAULT_BN, DEFAULT_BK, pad1, pad2, pick_block


def _gemm_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Grid (m, n, k): accumulate x_tile @ y_tile into the output tile.

    The output BlockSpec ignores the k axis, so o_ref revisits the same
    tile across the k loop — the canonical Pallas accumulation idiom.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _gemm_bn_relu_kernel(x_ref, y_ref, scale_ref, shift_ref, o_ref, *, nk: int):
    """Same as :func:`_gemm_kernel` plus a fused affine+ReLU epilogue
    applied on the last k step, while the accumulator is still in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        acc = acc * scale_ref[...] + shift_ref[...]
        o_ref[...] = jnp.maximum(acc, 0.0)


def _blocks(m: int, n: int, k: int, bm, bn, bk):
    bm = bm or pick_block(m, DEFAULT_BM)
    bn = bn or pick_block(n, DEFAULT_BN)
    bk = bk or pick_block(k, DEFAULT_BK)
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(x, y, *, bm=None, bn=None, bk=None):
    """Dense ``x @ y`` with ragged edges zero-padded to the tile grid.

    x: (M, K) f32, y: (K, N) f32 -> (M, N) f32.
    """
    m, kdim = x.shape
    k2, n = y.shape
    assert kdim == k2, f"inner dims mismatch: {kdim} vs {k2}"
    bm_, bn_, bk_ = _blocks(m, n, kdim, bm, bn, bk)
    xp = pad2(x.astype(jnp.float32), bm_, bk_)
    yp = pad2(y.astype(jnp.float32), bk_, bn_)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk_
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_bn_relu(x, y, scale, shift, *, bm=None, bn=None, bk=None):
    """Fused ``relu((x @ y) * scale + shift)`` — scale/shift broadcast over
    rows (per output channel), i.e. an inference-time BatchNorm folded to
    per-column affine.

    x: (M, K), y: (K, N), scale/shift: (N,).
    """
    m, kdim = x.shape
    k2, n = y.shape
    assert kdim == k2
    assert scale.shape == (n,) and shift.shape == (n,)
    bm_, bn_, bk_ = _blocks(m, n, kdim, bm, bn, bk)
    xp = pad2(x.astype(jnp.float32), bm_, bk_)
    yp = pad2(y.astype(jnp.float32), bk_, bn_)
    sp = pad1(scale.astype(jnp.float32), bn_).reshape(1, -1)
    hp = pad1(shift.astype(jnp.float32), bn_).reshape(1, -1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk_
    out = pl.pallas_call(
        functools.partial(_gemm_bn_relu_kernel, nk=nk),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp, sp, hp)
    return out[:m, :n]
