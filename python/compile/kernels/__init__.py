"""CADNN Layer-1 Pallas kernels.

Each kernel is the TPU-adapted version of one of the paper's
architecture-aware mobile kernels (DESIGN.md §Hardware-Adaptation):

- ``gemm``         — tiled dense matmul (the paper's 1x1-conv->GEMM target)
- ``sparse_gemm``  — block-sparse matmul (tile-level skipping of pruned work)
- ``conv_fused``   — fused Conv+BN+ReLU via im2col-GEMM in a single kernel
- ``depthwise``    — fused DepthwiseConv+BN+ReLU

All kernels lower with ``interpret=True`` so the emitted HLO runs on any
PJRT backend (the rust CPU client in particular). ``ref.py`` holds the
pure-jnp oracles used by pytest.
"""

from .gemm import gemm, gemm_bn_relu
from .sparse_gemm import sparse_gemm, sparse_gemm_bn_relu
from .conv_fused import conv2d_fused, conv1x1_as_gemm
from .depthwise import depthwise_fused

__all__ = [
    "gemm",
    "gemm_bn_relu",
    "sparse_gemm",
    "sparse_gemm_bn_relu",
    "conv2d_fused",
    "conv1x1_as_gemm",
    "depthwise_fused",
]
