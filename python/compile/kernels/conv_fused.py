"""Fused Conv+BN+ReLU via im2col-GEMM, plus the 1x1-conv->GEMM fast path.

This is the paper's "model computation fusion and transformation" (§4)
rendered for TPU: the convolution is lowered to an im2col patch matrix
(the layout transformation) followed by a *single* Pallas kernel that does
GEMM + folded-BatchNorm affine + ReLU on the VMEM-resident accumulator.
On the phone the fusion saved a DRAM round trip per intermediate; here it
saves the HBM round trip in exactly the same place.

The 1x1 stride-1 path skips im2col entirely — a (N*H*W, Cin) x (Cin, Cout)
matmul — which is the paper's "transform the convolution operation into
matrix multiplication" observation, applied literally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gemm import gemm, gemm_bn_relu
from .sparse_gemm import sparse_gemm_bn_relu


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, padding: int):
    """NHWC input -> (N*Ho*Wo, kh*kw*C) patch matrix.

    Static shapes throughout so the whole thing lowers into the AOT HLO.
    """
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + stride * ho : stride, j : j + stride * wo : stride, :]
            cols.append(patch)
    # (N, Ho, Wo, kh*kw*C) with the (i, j, c) minor order matching a
    # HWIO->(kh*kw*Cin, Cout) weight reshape.
    stacked = jnp.concatenate(cols, axis=-1)
    return stacked.reshape(n * ho * wo, kh * kw * c), (n, ho, wo)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "relu", "bm", "bn", "bk")
)
def conv2d_fused(
    x,
    w,
    scale,
    shift,
    *,
    stride: int = 1,
    padding: int = 0,
    relu: bool = True,
    bm=None,
    bn=None,
    bk=None,
):
    """Fused Conv2d+BN(+ReLU).

    x: (N, H, W, Cin) NHWC; w: (kh, kw, Cin, Cout) HWIO;
    scale/shift: (Cout,) — the inference-folded BatchNorm affine
    (scale = gamma/sqrt(var+eps), shift = beta - mean*scale).
    """
    kh, kw, cin, cout = w.shape
    wmat = w.reshape(kh * kw * cin, cout)
    if kh == 1 and kw == 1 and stride == 1 and padding == 0:
        n, h, wd, _ = x.shape
        xm = x.reshape(n * h * wd, cin)
        meta = (n, h, wd)
    else:
        xm, meta = im2col(x, kh, kw, stride, padding)
    if relu:
        out = gemm_bn_relu(xm, wmat, scale, shift, bm=bm, bn=bn, bk=bk)
    else:
        out = gemm(xm, wmat, bm=bm, bn=bn, bk=bk) * scale.reshape(1, -1) + shift.reshape(1, -1)
    n, ho, wo = meta
    return out.reshape(n, ho, wo, cout)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def conv1x1_as_gemm(x, w, *, bm=None, bn=None, bk=None):
    """Bare 1x1 convolution as a GEMM (no epilogue): the paper's
    transformation in isolation, used by the transformation-ablation tests."""
    n, h, wd, cin = x.shape
    assert w.shape[:2] == (1, 1)
    out = gemm(x.reshape(n * h * wd, cin), w.reshape(cin, -1), bm=bm, bn=bn, bk=bk)
    return out.reshape(n, h, wd, -1)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "bm", "bn", "bk")
)
def conv2d_sparse_fused(
    x,
    w,
    mask,
    scale,
    shift,
    *,
    stride: int = 1,
    padding: int = 0,
    bm=None,
    bn=None,
    bk=None,
):
    """Compressed fused conv: weights carry a (K/bk, Cout/bn) tile mask from
    the ADMM compressor; pruned weight tiles are skipped in the kernel."""
    kh, kw, cin, cout = w.shape
    wmat = w.reshape(kh * kw * cin, cout)
    if kh == 1 and kw == 1 and stride == 1 and padding == 0:
        n, h, wd, _ = x.shape
        xm = x.reshape(n * h * wd, cin)
        meta = (n, h, wd)
    else:
        xm, meta = im2col(x, kh, kw, stride, padding)
    out = sparse_gemm_bn_relu(xm, wmat, mask, scale, shift, bm=bm, bn=bn, bk=bk)
    n, ho, wo = meta
    return out.reshape(n, ho, wo, cout)
