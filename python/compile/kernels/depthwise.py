"""Fused DepthwiseConv+BN+ReLU Pallas kernel.

The paper calls out "Depthwise Convolution layer + BatchNorm layer +
Activation layer in MobileNetV1" as a fusion target (§4). A depthwise conv
has no reduction over channels, so the MXU is useless — on TPU this is a
VPU (vector-unit) kernel, exactly as it is a plain-SIMD (not GEMM) kernel
on the phone's CPU. The grid partitions (batch, channel-blocks); each
program holds its input slab in VMEM and produces the fused
conv+affine+relu output slab without intermediate HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pick_block, round_up


def _dw_kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref, *, kh, kw, stride, ho, wo):
    """One (batch, channel-block) program: fully unrolled kh x kw taps."""
    x = x_ref[0]  # (Hp, Wp, bc)
    acc = jnp.zeros((ho, wo, x.shape[-1]), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            tap = x[i : i + stride * ho : stride, j : j + stride * wo : stride, :]
            acc = acc + tap * w_ref[i, j, :]
    acc = acc * scale_ref[...] + shift_ref[...]
    o_ref[0] = jnp.maximum(acc, 0.0)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "bc"))
def depthwise_fused(x, w, scale, shift, *, stride: int = 1, padding: int = 0, bc=None):
    """Fused depthwise conv + folded BN + ReLU.

    x: (N, H, W, C) NHWC; w: (kh, kw, C); scale/shift: (C,).
    """
    n, h, wd, c = x.shape
    kh, kw, cw = w.shape
    assert cw == c, f"channel mismatch {cw} vs {c}"
    bc_ = bc or pick_block(c, 128)
    cp = round_up(c, bc_)
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    if cp != c:
        pad_c = cp - c
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad_c)))
        scale = jnp.pad(scale, ((0, pad_c),))
        shift = jnp.pad(shift, ((0, pad_c),))
    hp, wp = x.shape[1], x.shape[2]
    ho = (hp - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    out = pl.pallas_call(
        functools.partial(
            _dw_kernel, kh=kh, kw=kw, stride=stride, ho=ho, wo=wo
        ),
        grid=(n, cp // bc_),
        in_specs=[
            pl.BlockSpec((1, hp, wp, bc_), lambda b, cb: (b, 0, 0, cb)),
            pl.BlockSpec((kh, kw, bc_), lambda b, cb: (0, 0, cb)),
            pl.BlockSpec((bc_,), lambda b, cb: (cb,)),
            pl.BlockSpec((bc_,), lambda b, cb: (cb,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bc_), lambda b, cb: (b, 0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cp), jnp.float32),
        interpret=True,
    )(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        scale.astype(jnp.float32),
        shift.astype(jnp.float32),
    )
    return out[..., :c]
