"""Pure-python reader for the `.cadnn` textual model IR.

A transliteration of the Rust front-end (`rust/src/front/`, grammar in
docs/MODEL_FORMAT.md) so the python compression pipeline can consume the
same user-defined model files the Rust planner and server do — same
tokenizer, same per-op validation, same shape inference, same per-layer
weight accounting. No jax/numpy: this is pure accounting, importable
from anywhere (compress_run uses it for `--model-file` reports).

Malformed input raises :class:`ParseError` (a ``ValueError``) whose
message matches the Rust diagnostic shape:
``parse error at L:C near 'tok': reason``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Anti-DoS caps — keep in sync with rust/src/front/parser.rs.
MAX_RANK = 8
MAX_DIM = 1 << 20
MAX_NUMEL = 1 << 31
MAX_WEIGHTS = 1 << 31
MAX_KERNEL = 1 << 10
MAX_RECEPTIVE = 1 << 20
MAX_NODES = 2048
MAX_ATTR_INT = 1 << 31


class ParseError(ValueError):
    def __init__(self, line, col, token, reason):
        self.line, self.col, self.token, self.reason = line, col, token, reason
        super().__init__(f"parse error at {line}:{col} near '{token}': {reason}")


# ---------------------------------------------------------------- lexer

_PUNCT = {"=": "eq", "(": "lparen", ")": "rparen", "[": "lbracket",
          "]": "rbracket", ",": "comma"}


@dataclass
class Token:
    kind: str  # ident|str|int|pair|float|eq|lparen|rparen|lbracket|rbracket|comma|newline|eof
    value: object
    line: int
    col: int

    def display(self):
        if self.kind == "str":
            return f'"{self.value}"'
        if self.kind == "pair":
            return f"{self.value[0]}x{self.value[1]}"
        if self.kind == "newline":
            return "<newline>"
        if self.kind == "eof":
            return "<eof>"
        return str(self.value)


def lex(src):
    toks, line, col, i, n = [], 1, 1, 0, len(src)
    while i < n:
        c = src[i]
        tl, tc = line, col
        if c == "\n":
            toks.append(Token("newline", "\n", tl, tc))
            i, line, col = i + 1, line + 1, 1
        elif c in " \t\r":
            i, col = i + 1, col + 1
        elif c == "#":
            while i < n and src[i] != "\n":
                i, col = i + 1, col + 1
        elif c in _PUNCT:
            toks.append(Token(_PUNCT[c], c, tl, tc))
            i, col = i + 1, col + 1
        elif c == '"':
            i, col = i + 1, col + 1
            out = []
            while True:
                if i >= n or src[i] == "\n":
                    raise ParseError(tl, tc, '"', "unterminated string")
                if src[i] == '"':
                    i, col = i + 1, col + 1
                    break
                if src[i] == "\\":
                    if i + 1 >= n:
                        raise ParseError(tl, tc, '"', "unterminated string")
                    e = src[i + 1]
                    if e not in ('"', "\\"):
                        raise ParseError(line, col, f"\\{e}",
                                         'unknown escape (use \\" or \\\\)')
                    out.append(e)
                    i, col = i + 2, col + 2
                else:
                    out.append(src[i])
                    i, col = i + 1, col + 1
            toks.append(Token("str", "".join(out), tl, tc))
        elif c.isdigit():
            j = i
            while j < n and src[j].isdigit():
                j += 1
            a = src[i:j]
            if j + 1 < n and src[j] == "." and src[j + 1].isdigit():
                k = j + 1
                while k < n and src[k].isdigit():
                    k += 1
                tok = Token("float", float(f"{a}.{src[j + 1:k]}"), tl, tc)
                j = k
            elif j + 1 < n and src[j] == "x" and src[j + 1].isdigit():
                k = j + 1
                while k < n and src[k].isdigit():
                    k += 1
                x, y = int(a), int(src[j + 1:k])
                if x >= 2**64 or y >= 2**64:
                    raise ParseError(tl, tc, f"{a}x{src[j + 1:k]}",
                                     "dimension pair too large")
                tok = Token("pair", (x, y), tl, tc)
                j = k
            else:
                v = int(a)
                if v >= 2**64:
                    raise ParseError(tl, tc, a, "integer literal too large")
                tok = Token("int", v, tl, tc)
            col += j - i
            i = j
            toks.append(tok)
        elif c.isascii() and (c.isalpha() or c == "_"):
            j = i
            while j < n and src[j].isascii() and (src[j].isalnum() or src[j] == "_"):
                j += 1
            toks.append(Token("ident", src[i:j], tl, tc))
            col += j - i
            i = j
        else:
            raise ParseError(tl, tc, c, "unexpected character")
    toks.append(Token("eof", "", line, col))
    return toks


# --------------------------------------------------------------- model


@dataclass
class Node:
    name: str
    op: str          # op name as written (canonical, e.g. "conv2d")
    inputs: list     # node indices
    shape: list      # output shape
    params: dict     # op attributes (kh, kw, cout, stride, ...)
    weight_count: int
    aux_params: int
    prunable: bool


@dataclass
class Model:
    name: str
    nodes: list
    output: int
    # per-layer hints keyed by node name
    sparsity: dict = field(default_factory=dict)
    structures: dict = field(default_factory=dict)
    quant: dict = field(default_factory=dict)

    def weight_total(self):
        return sum(nd.weight_count for nd in self.nodes)

    def prunable_nodes(self):
        return [nd for nd in self.nodes if nd.prunable]


def _prod(dims):
    out = 1
    for d in dims:
        out *= d
    return out


def parse_structure(label):
    """`element` / `block<R>x<C>` / `pattern<N>` → label, or None."""
    if label == "element":
        return label
    if label.startswith("block"):
        body = label[len("block"):].split("x")
        if len(body) == 2 and all(p.isdigit() and int(p) > 0 for p in body):
            return label
    if label.startswith("pattern") and label[len("pattern"):].isdigit():
        if int(label[len("pattern"):]) > 0:
            return label
    return None


# --------------------------------------------------------------- parser


class _Parser:
    def __init__(self, toks):
        self.toks = toks
        self.pos = 0

    def peek(self):
        return self.toks[self.pos]

    def next(self):
        t = self.toks[self.pos]
        if t.kind != "eof":
            self.pos += 1
        return t

    def err(self, t, reason):
        raise ParseError(t.line, t.col, t.display(), reason)

    def skip_newlines(self):
        while self.peek().kind == "newline":
            self.pos += 1

    def name(self, what):
        t = self.next()
        if t.kind not in ("ident", "str"):
            self.err(t, f"expected {what}")
        return t.value, t

    def end_of_stmt(self):
        t = self.next()
        if t.kind not in ("newline", "eof"):
            self.err(t, "expected end of line")

    def shape_literal(self):
        opn = self.next()
        if opn.kind != "lbracket":
            self.err(opn, "expected '[' to start a shape")
        dims = []
        while True:
            t = self.next()
            if t.kind != "int":
                self.err(t, "expected a dimension (positive integer)")
            if not 1 <= t.value <= MAX_DIM:
                self.err(t, f"dimension must be in 1..={MAX_DIM}")
            dims.append(t.value)
            t = self.next()
            if t.kind == "comma":
                continue
            if t.kind == "rbracket":
                break
            self.err(t, "expected ',' or ']' in shape")
        if len(dims) > MAX_RANK:
            self.err(opn, f"shape rank {len(dims)} exceeds max {MAX_RANK}")
        if _prod(dims) > MAX_NUMEL:
            self.err(opn, f"shape has {_prod(dims)} elements; max {MAX_NUMEL}")
        return dims

    def attrs(self):
        out = []
        while self.peek().kind == "ident":
            kt = self.next()
            key = kt.value
            if any(a[0] == key for a in out):
                self.err(kt, f"duplicate attribute '{key}'")
            if self.peek().kind == "eq":
                self.pos += 1
                if self.peek().kind == "lbracket":
                    val = ("shape", self.shape_literal())
                else:
                    vt = self.next()
                    if vt.kind not in ("int", "pair", "float", "ident"):
                        self.err(vt, f"expected a value for '{key}'")
                    val = (vt.kind, vt.value)
            else:
                val = ("flag", None)
            out.append((key, val, kt))
        return out


class _Attrs:
    def __init__(self, items):
        self.items = items

    def take(self, key):
        for i, a in enumerate(self.items):
            if a[0] == key:
                return self.items.pop(i)
        return None

    def _perr(self, a, reason):
        raise ParseError(a[2].line, a[2].col, a[0], reason)

    def req_int(self, key, maximum, ot):
        a = self.take(key)
        if a is None:
            raise ParseError(ot.line, ot.col, ot.display(),
                             f"missing required attribute '{key}'")
        kind, v = a[1]
        if kind == "int" and 1 <= v <= maximum:
            return v
        if kind == "int":
            raise ParseError(a[2].line, a[2].col, str(v),
                             f"'{key}' must be in 1..={maximum}")
        self._perr(a, f"'{key}' takes a positive integer")

    def opt_int(self, key, default, lo, hi):
        a = self.take(key)
        if a is None:
            return default
        kind, v = a[1]
        if kind == "int" and lo <= v <= hi:
            return v
        self._perr(a, f"'{key}' must be an integer in {lo}..={hi}")

    def req_k(self, ot):
        a = self.take("k")
        if a is None:
            raise ParseError(ot.line, ot.col, ot.display(),
                             "missing required attribute 'k'")
        kind, v = a[1]
        if kind == "int":
            kh = kw = v
        elif kind == "pair":
            kh, kw = v
        else:
            raise ParseError(a[2].line, a[2].col, "k",
                             "'k' takes an integer or HxW pair")
        if not (1 <= kh <= MAX_KERNEL and 1 <= kw <= MAX_KERNEL):
            raise ParseError(a[2].line, a[2].col, "k",
                             f"kernel dims must be in 1..={MAX_KERNEL}")
        return kh, kw

    def opt_pad(self):
        a = self.take("pad")
        if a is None:
            return 0, 0
        kind, v = a[1]
        if kind == "int":
            ph = pw = v
        elif kind == "pair":
            ph, pw = v
        else:
            raise ParseError(a[2].line, a[2].col, "pad",
                             "'pad' takes an integer or HxW pair")
        if ph > MAX_KERNEL or pw > MAX_KERNEL:
            raise ParseError(a[2].line, a[2].col, "pad",
                             f"padding must be <= {MAX_KERNEL}")
        return ph, pw

    def opt_pad_sym(self):
        a = self.take("pad")
        if a is None:
            return 0
        kind, v = a[1]
        if kind == "int" and v <= MAX_KERNEL:
            return v
        if kind == "int":
            raise ParseError(a[2].line, a[2].col, "pad",
                             f"padding must be <= {MAX_KERNEL}")
        raise ParseError(a[2].line, a[2].col, "pad",
                         "this op takes a single symmetric 'pad' integer")

    def flag(self, key):
        a = self.take(key)
        if a is None:
            return False
        if a[1][0] == "flag":
            return True
        self._perr(a, f"'{key}' is a flag and takes no value")

    def act(self, ot):
        a = self.take("act")
        if a is None:
            raise ParseError(ot.line, ot.col, ot.display(),
                             "missing required attribute 'act'")
        kind, v = a[1]
        if kind == "ident" and v in ("relu", "relu6", "none"):
            return v
        raise ParseError(a[2].line, a[2].col, "act",
                         "'act' must be relu, relu6 or none")

    def req_shape(self, key, ot):
        a = self.take(key)
        if a is None:
            raise ParseError(ot.line, ot.col, ot.display(),
                             f"missing required attribute '{key}'")
        if a[1][0] == "shape":
            return a[1][1]
        self._perr(a, f"'{key}' takes a shape like [1,56,56,64]")

    def take_hints(self):
        sp, pr, qu = self.take("sparsity"), self.take("prune"), self.take("quant")
        if sp is None:
            if pr is not None or qu is not None:
                a = pr if pr is not None else qu
                self._perr(a, "'prune'/'quant' hints require a 'sparsity' hint")
            return None
        kind, v = sp[1]
        if kind == "float":
            s = v
        elif kind == "int":
            s = float(v)
        else:
            raise ParseError(sp[2].line, sp[2].col, "sparsity",
                             "'sparsity' takes a fraction like 0.9")
        if not 0.0 <= s < 1.0:
            raise ParseError(sp[2].line, sp[2].col, "sparsity",
                             "'sparsity' must be in [0, 1)")
        structure = "element"
        if pr is not None:
            kind, v = pr[1]
            if kind != "ident":
                raise ParseError(pr[2].line, pr[2].col, "prune",
                                 "'prune' takes a label like block4x4")
            structure = parse_structure(v)
            if structure is None:
                raise ParseError(pr[2].line, pr[2].col, v,
                                 "unknown prune structure (element | block<R>x<C> | pattern<N>)")
        quant = None
        if qu is not None:
            kind, v = qu[1]
            if kind != "int" or not 2 <= v <= 8:
                raise ParseError(qu[2].line, qu[2].col, "quant",
                                 "'quant' takes a bit width in 2..=8")
            quant = v
        return s, structure, quant, sp[2]

    def finish(self, op_name):
        if self.items:
            a = self.items[0]
            self._perr(a, f"unknown attribute '{a[0]}' for op '{op_name}'")


def _one_input(op_name, ot, ins):
    if len(ins) != 1:
        raise ParseError(ot.line, ot.col, op_name,
                         f"'{op_name}' takes exactly 1 input, got {len(ins)}")
    return ins[0]


def _rank4(op_name, ot, s):
    if len(s) != 4:
        raise ParseError(ot.line, ot.col, op_name,
                         f"'{op_name}' needs a rank-4 NHWC input, got rank {len(s)}")


def _window_fits(op_name, ot, s, kh, kw, ph, pw):
    if s[1] + 2 * ph < kh or s[2] + 2 * pw < kw:
        raise ParseError(ot.line, ot.col, op_name,
                         f"window {kh}x{kw} with pad {ph}x{pw} does not fit "
                         f"input {s[1]}x{s[2]}")


def _check_numel(ot, numel):
    if numel > MAX_NUMEL:
        raise ParseError(ot.line, ot.col, ot.display(),
                         f"output has {numel} elements; max {MAX_NUMEL}")


def _weights_err(ot, op_name):
    raise ParseError(ot.line, ot.col, op_name,
                     f"layer weight count exceeds max {MAX_WEIGHTS}")


def _shape_str(s):
    return "[" + ",".join(str(d) for d in s) + "]"


def _build_op(op_name, ot, ins, attrs):
    """Validate attributes for `op_name` and return
    (params, out_shape, weight_count, aux_params, prunable)."""
    if op_name in ("conv2d", "fused_conv_bn_act"):
        s = _one_input(op_name, ot, ins)
        _rank4(op_name, ot, s)
        kh, kw = attrs.req_k(ot)
        cout = attrs.req_int("cout", MAX_ATTR_INT, ot)
        stride = attrs.opt_int("stride", 1, 1, MAX_DIM)
        padh, padw = attrs.opt_pad()
        groups = attrs.opt_int("groups", 1, 1, MAX_DIM)
        cin = s[3]
        if cin % groups or cout % groups:
            raise ParseError(ot.line, ot.col, op_name,
                             f"groups={groups} must divide both cin={cin} and cout={cout}")
        _window_fits(op_name, ot, s, kh, kw, padh, padw)
        receptive = kh * kw * (cin // groups)
        if receptive > MAX_RECEPTIVE:
            raise ParseError(ot.line, ot.col, op_name,
                             f"receptive field {receptive} too large (max {MAX_RECEPTIVE})")
        if receptive * cout > MAX_WEIGHTS:
            _weights_err(ot, op_name)
        oh = (s[1] + 2 * padh - kh) // stride + 1
        ow = (s[2] + 2 * padw - kw) // stride + 1
        _check_numel(ot, s[0] * oh * ow * cout)
        params = dict(kh=kh, kw=kw, cin=cin, cout=cout, stride=stride,
                      padh=padh, padw=padw, groups=groups)
        wc = kh * kw * (cin // groups) * cout
        if op_name == "conv2d":
            params["bias"] = attrs.flag("bias")
            aux = cout if params["bias"] else 0
        else:
            params["act"] = attrs.act(ot)
            aux = 2 * cout
        return params, [s[0], oh, ow, cout], wc, aux, True
    if op_name in ("dwconv2d", "fused_dw_bn_act"):
        s = _one_input(op_name, ot, ins)
        _rank4(op_name, ot, s)
        kh, kw = attrs.req_k(ot)
        stride = attrs.opt_int("stride", 1, 1, MAX_DIM)
        padding = attrs.opt_pad_sym()
        c = s[3]
        _window_fits(op_name, ot, s, kh, kw, padding, padding)
        if kh * kw * c > MAX_WEIGHTS:
            _weights_err(ot, op_name)
        oh = (s[1] + 2 * padding - kh) // stride + 1
        ow = (s[2] + 2 * padding - kw) // stride + 1
        _check_numel(ot, s[0] * oh * ow * c)
        params = dict(kh=kh, kw=kw, c=c, stride=stride, padding=padding)
        aux = 0
        if op_name == "fused_dw_bn_act":
            params["act"] = attrs.act(ot)
            aux = 2 * c
        return params, [s[0], oh, ow, c], kh * kw * c, aux, False
    if op_name == "batchnorm":
        s = _one_input(op_name, ot, ins)
        return dict(c=s[-1]), list(s), 0, 4 * s[-1], False
    if op_name in ("relu", "relu6", "identity"):
        s = _one_input(op_name, ot, ins)
        return dict(), list(s), 0, 0, False
    if op_name in ("maxpool", "avgpool"):
        s = _one_input(op_name, ot, ins)
        _rank4(op_name, ot, s)
        k = attrs.req_int("k", MAX_KERNEL, ot)
        stride = attrs.opt_int("stride", k, 1, MAX_DIM)
        padding = attrs.opt_pad_sym()
        _window_fits(op_name, ot, s, k, k, padding, padding)
        oh = (s[1] + 2 * padding - k) // stride + 1
        ow = (s[2] + 2 * padding - k) // stride + 1
        _check_numel(ot, s[0] * oh * ow * s[3])
        return (dict(k=k, stride=stride, padding=padding),
                [s[0], oh, ow, s[3]], 0, 0, False)
    if op_name == "global_avg_pool":
        s = _one_input(op_name, ot, ins)
        _rank4(op_name, ot, s)
        return dict(), [s[0], s[3]], 0, 0, False
    if op_name in ("dense", "fc"):
        s = _one_input(op_name, ot, ins)
        if len(s) != 2:
            raise ParseError(ot.line, ot.col, op_name,
                             f"'{op_name}' needs a rank-2 [batch, features] input "
                             f"(got rank {len(s)}); insert flatten or "
                             f"global_avg_pool first")
        cout = attrs.req_int("cout", MAX_ATTR_INT, ot)
        bias = attrs.flag("bias")
        cin = s[1]
        if cin * cout > MAX_WEIGHTS:
            _weights_err(ot, op_name)
        _check_numel(ot, s[0] * cout)
        return (dict(cin=cin, cout=cout, bias=bias), [s[0], cout],
                cin * cout, cout if bias else 0, True)
    if op_name == "add":
        if len(ins) != 2:
            raise ParseError(ot.line, ot.col, op_name,
                             f"'add' takes exactly 2 inputs, got {len(ins)}")
        if ins[0] != ins[1]:
            raise ParseError(ot.line, ot.col, op_name,
                             f"'add' inputs must have identical shapes, got "
                             f"{_shape_str(ins[0])} vs {_shape_str(ins[1])}")
        return dict(), list(ins[0]), 0, 0, False
    if op_name == "concat":
        if len(ins) < 2:
            raise ParseError(ot.line, ot.col, op_name,
                             f"'concat' takes at least 2 inputs, got {len(ins)}")
        for s in ins:
            _rank4(op_name, ot, s)
        s0 = ins[0]
        for s in ins[1:]:
            if s[:3] != s0[:3]:
                raise ParseError(ot.line, ot.col, op_name,
                                 f"'concat' inputs must share N/H/W, got "
                                 f"{_shape_str(s)} vs {_shape_str(s0)}")
        _check_numel(ot, sum(_prod(s) for s in ins))
        c = sum(s[3] for s in ins)
        return dict(), [s0[0], s0[1], s0[2], c], 0, 0, False
    if op_name == "softmax":
        s = _one_input(op_name, ot, ins)
        return dict(), list(s), 0, 0, False
    if op_name == "flatten":
        s = _one_input(op_name, ot, ins)
        return dict(), [s[0], _prod(s[1:])], 0, 0, False
    if op_name == "gemm":
        s = _one_input(op_name, ot, ins)
        m = attrs.req_int("m", MAX_ATTR_INT, ot)
        k = attrs.req_int("k", MAX_ATTR_INT, ot)
        nn = attrs.req_int("n", MAX_ATTR_INT, ot)
        act = attrs.act(ot)
        epilogue = attrs.flag("epilogue")
        out_shape = attrs.req_shape("out", ot)
        if m * k != _prod(s):
            raise ParseError(ot.line, ot.col, op_name,
                             f"gemm m*k = {m * k} must equal input numel {_prod(s)}")
        if m * nn != _prod(out_shape):
            raise ParseError(ot.line, ot.col, op_name,
                             f"gemm m*n = {m * nn} must equal output numel "
                             f"{_prod(out_shape)}")
        if k * nn > MAX_WEIGHTS:
            _weights_err(ot, op_name)
        aux = 2 * nn if epilogue else nn
        return (dict(m=m, k=k, n=nn, act=act, epilogue=epilogue),
                list(out_shape), k * nn, aux, True)
    raise ParseError(ot.line, ot.col, op_name,
                     f"unknown op '{op_name}' (expected conv2d, dwconv2d, batchnorm, "
                     f"relu, relu6, identity, maxpool, avgpool, global_avg_pool, "
                     f"dense, add, concat, softmax, flatten, fused_conv_bn_act, "
                     f"fused_dw_bn_act, gemm)")


def parse(src):
    """Parse `.cadnn` source into a :class:`Model`."""
    p = _Parser(lex(src))
    p.skip_newlines()
    t = p.next()
    if not (t.kind == "ident" and t.value == "model"):
        p.err(t, "expected 'model <name>' header")
    model_name, _ = p.name("a model name")
    p.end_of_stmt()
    p.skip_newlines()
    t = p.next()
    if not (t.kind == "ident" and t.value == "input"):
        p.err(t, "expected 'input <name> [dims]' after the model header")
    input_name, _ = p.name("an input name")
    shape = p.shape_literal()
    p.end_of_stmt()

    model = Model(model_name,
                  [Node(input_name, "input", [], shape, {}, 0, 0, False)], 0)
    ids = {input_name: 0}

    while True:
        p.skip_newlines()
        if p.peek().kind == "eof":
            break
        name, nt = p.name("a node name or 'output'")
        if p.peek().kind != "eq":
            if name == "output":
                target, tt = p.name("an output node name")
                if target not in ids:
                    raise ParseError(tt.line, tt.col, target,
                                     f"output references unknown node '{target}'")
                model.output = ids[target]
                p.end_of_stmt()
                p.skip_newlines()
                if p.peek().kind != "eof":
                    p.err(p.peek(), "'output' must be the last statement")
                break
            if name == "input":
                p.err(nt, "duplicate 'input' statement (a model has exactly one)")
            p.err(p.peek(), f"expected '=' after node name '{name}'")
        if name in ids:
            p.err(nt, f"duplicate node name '{name}'")
        p.pos += 1  # consume '='
        ot = p.next()
        if ot.kind != "ident":
            p.err(ot, "expected an op name")
        op_name = ot.value
        t = p.next()
        if t.kind != "lparen":
            p.err(t, f"expected '(' after op '{op_name}'")
        args = []
        if p.peek().kind == "rparen":
            p.err(p.next(), f"'{op_name}' needs at least one input")
        while True:
            an, at = p.name("an op input name")
            if an not in ids:
                raise ParseError(at.line, at.col, an,
                                 f"unknown input '{an}' (nodes must be defined before use)")
            args.append(ids[an])
            t = p.next()
            if t.kind == "comma":
                continue
            if t.kind == "rparen":
                break
            p.err(t, "expected ',' or ')' in op inputs")
        attrs = _Attrs(p.attrs())
        hints = attrs.take_hints()
        if len(model.nodes) >= MAX_NODES:
            raise ParseError(nt.line, nt.col, name,
                             f"model too large (max {MAX_NODES} nodes)")
        ins = [model.nodes[i].shape for i in args]
        params, out_shape, wc, aux, prunable = _build_op(op_name, ot, ins, attrs)
        attrs.finish(op_name)
        if hints is not None:
            s, structure, quant, st = hints
            if not prunable:
                raise ParseError(st.line, st.col, "sparsity",
                                 f"sparsity hints only apply to weight layers; "
                                 f"'{op_name}' is not one")
            model.sparsity[name] = s
            if structure != "element":
                model.structures[name] = structure
            if quant is not None:
                model.quant[name] = quant
        model.output = len(model.nodes)
        model.nodes.append(Node(name, op_name, args, out_shape, params, wc, aux,
                                prunable))
        ids[name] = model.output
        p.end_of_stmt()
    return model


def parse_file(path):
    with open(path, "r", encoding="utf-8") as f:
        return parse(f.read())


def accounting_report(model):
    """Per-layer pruning accounting for a hinted model, shaped like the
    `measured.<name>.per_layer` entries of compress_report.json so the
    Rust `cadnn compress --report` reader and `SparsityProfile::from_report`
    consume it unchanged (layer names == parsed node names)."""
    per_layer, total, nnz = {}, 0, 0
    for nd in model.prunable_nodes():
        s = model.sparsity.get(nd.name, 0.0)
        keep = int(round(nd.weight_count * (1.0 - s)))
        per_layer[nd.name] = {
            "nnz": keep,
            "total": nd.weight_count,
            "structure": model.structures.get(nd.name, "element"),
            "quant": model.quant.get(nd.name),
        }
        total += nd.weight_count
        nnz += keep
    return {
        "model": model.name,
        "total_weights": total,
        "nnz": nnz,
        "rate": round(total / nnz, 1) if nnz else None,
        "per_layer": per_layer,
    }
