"""§3 compression experiments: regenerate the paper's pruning-rate claims.

Two kinds of evidence (DESIGN.md §2, §5):

1. **Measured** — LeNet-5 on the synthetic digit task: dense baseline,
   aggressive element-wise ADMM pruning (paper: 348x overall / 0.28%
   weights remaining), and unified pruning+quantization (paper: up to
   3,438x storage, indices not counted). We run the full pipeline and
   report achieved rate + accuracy delta. The *absolute* rate at equal
   accuracy depends on task difficulty (our synthetic task is easier than
   MNIST, so very high rates are reachable); the claim-shape under test is
   "two orders of magnitude at ~no accuracy loss".

2. **Accounted** — AlexNet / VGG-16 / ResNet-18 / ResNet-50: the paper's
   per-layer pruning profiles (from the ADMM papers it builds on) applied
   to the exact architectures, yielding overall weight reduction and
   storage. These architectures cannot be trained here (no ImageNet), so
   rates are computed from the profiles, never measured accuracy.

Emits artifacts/compress_report.json; `examples/compress_report.rs`
cross-checks the accounted numbers against the Rust `compress::size`
module.

A third mode, `--model-file path/to/model.cadnn`, skips training and
emits pure accounting for a user-defined textual model (the same
`.cadnn` dialect the Rust front-end parses — see docs/MODEL_FORMAT.md):
per-layer nnz/total/structure/quant derived from the file's inline
`sparsity=` hints, keyed by the parsed node names so the Rust
`SparsityProfile` report reader matches layers without renaming.

Usage: python -m compile.compress_run [--out ../artifacts/compress_report.json] [--quick]
       python -m compile.compress_run --model-file models/resnet50.cadnn [--out ...]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from . import admm as A
from . import cadnn_ir
from . import datasets as D
from . import model as M
from . import train as T

# Paper-prescribed overall rates (§3) used as accounting targets.
PAPER_RATES = {
    "lenet5": 348.0,
    "alexnet": 36.0,
    "vgg16": 34.0,
    "resnet18": 8.0,   # abstract: 8x with (almost) zero accuracy loss
    "resnet50": 9.2,
}

# Per-layer non-uniform profiles for the accounted subjects: conv layers
# prune less, FC layers prune much more (the ADMM papers' shape). Each
# entry: (layer kind, #weights, sparsity). Weights counts match the
# canonical architectures; the Rust models/ module re-derives them
# independently and the compress_report example cross-checks.
ACCOUNTED_PROFILES = {
    "alexnet": [
        ("conv1", 34_848, 0.16),
        ("conv2", 307_200, 0.65),
        ("conv3", 884_736, 0.70),
        ("conv4", 663_552, 0.66),
        ("conv5", 442_368, 0.66),
        ("fc6", 37_748_736, 0.988),
        ("fc7", 16_777_216, 0.986),
        ("fc8", 4_096_000, 0.95),
    ],
    "vgg16": [
        ("conv1_1", 1_728, 0.42),
        ("conv1_2", 36_864, 0.79),
        ("conv2_1", 73_728, 0.78),
        ("conv2_2", 147_456, 0.80),
        ("conv3_1", 294_912, 0.77),
        ("conv3_2", 589_824, 0.82),
        ("conv3_3", 589_824, 0.80),
        ("conv4_1", 1_179_648, 0.81),
        ("conv4_2", 2_359_296, 0.82),
        ("conv4_3", 2_359_296, 0.80),
        ("conv5_1", 2_359_296, 0.78),
        ("conv5_2", 2_359_296, 0.80),
        ("conv5_3", 2_359_296, 0.78),
        ("fc6", 102_760_448, 0.993),
        ("fc7", 16_777_216, 0.99),
        ("fc8", 4_096_000, 0.95),
    ],
}


def measured_lenet5(quick: bool, log, granularity: str = "element"):
    n = 1200 if quick else 4000
    x, y = D.synthetic_digits(n, seed=1)
    xt, yt = D.synthetic_digits(800, seed=2)
    fwd = lambda p, xx: M.lenet5_apply(p, xx, backend="ref")

    params = M.lenet5_init(0)
    params, _ = T.train(fwd, params, x, y, epochs=3 if quick else 8, log=log)
    dense_acc = T.accuracy(fwd, params, xt, yt)
    total = sum(int(np.prod(params[k]["w"].shape)) for k in M.LENET5_PRUNABLE)
    log(f"lenet5 dense acc={dense_acc:.4f} prunable weights={total}")

    # Aggressive element-wise targets shaped like the paper's per-layer
    # profile (conv light, fc heavy). With --granularity block/pattern
    # the conv constraints become structured (pattern degrades to
    # element on non-conv weights; note LeNet-5's 5x5 kernels exceed the
    # Rust pattern format's 16-position table, so its planner keeps
    # pattern-pruned 5x5 layers on CSR — 3x3 architectures are where
    # `pattern` pays end-to-end, see docs/PIPELINE.md).
    sparsity = {"c1": 0.65, "c2": 0.93, "f1": 0.997, "f2": 0.98}
    cfg = A.AdmmConfig(
        sparsity=sparsity,
        rho=2e-3,
        rho_factor=2.0,
        admm_iters=2 if quick else 5,
        epochs_per_iter=1 if quick else 2,
        retrain_epochs=3 if quick else 20,
        progressive_stages=(0.5, 0.8, 1.0),
        granularity=granularity,
        block=(4, 4),
        seed=0,
    )
    t0 = time.time()
    res = A.admm_prune(fwd, params, x, y, cfg, log=log)
    prune_acc = T.accuracy(fwd, res.params, xt, yt)
    log(
        f"lenet5 pruned acc={prune_acc:.4f} rate={res.overall_rate:.1f}x "
        f"({time.time()-t0:.0f}s)"
    )

    # Unified pruning + 4-bit quantization (storage claim): quantize ON
    # the recovered support — re-running the prune phase would churn it.
    import copy
    qparams = A.quantize_on_support(
        fwd, copy.deepcopy(res.params), res.masks, x, y, 4,
        rounds=2 if quick else 5, seed=1, log=log,
    )
    quant_acc = T.accuracy(fwd, qparams, xt, yt)
    # per-layer codebook export: the quantized params' distinct nonzero
    # levels, parsed by the Rust SparsityProfile so Auto planning picks
    # quantized (LUT) payloads for these layers
    quant_export = A.export_quant(qparams, sparsity, 4)
    nnz = sum(
        int(np.sum(np.asarray(qparams[k]["w"]) != 0.0)) for k in sparsity
    )
    dense_bytes = A.storage_bytes_dense(total)
    quant_bytes = A.storage_bytes_compressed(nnz, 4, index_bits=0)
    quant_bytes_idx = A.storage_bytes_compressed(nnz, 4, index_bits=16)
    log(
        f"lenet5 prune+quant acc={quant_acc:.4f} rate={total/max(nnz,1):.1f}x "
        f"storage {dense_bytes}/{quant_bytes} = {dense_bytes/max(quant_bytes,1):.0f}x"
    )
    return {
        "task": "synthetic-digits (MNIST substitute, DESIGN.md §2)",
        "dense_acc": round(float(dense_acc), 4),
        "pruned_acc": round(float(prune_acc), 4),
        "pruned_rate": round(float(res.overall_rate), 1),
        "per_layer": {
            k: {
                "nnz": v[0],
                "total": v[1],
                "structure": res.structures.get(k, "element"),
                "quant": quant_export[k],
            }
            for k, v in res.per_layer_nnz.items()
        },
        "quant_bits": 4,
        "quant_acc": round(float(quant_acc), 4),
        "quant_rate": round(float(total / max(nnz, 1)), 1),
        "storage_dense_bytes": dense_bytes,
        "storage_quant_bytes": quant_bytes,
        "storage_quant_bytes_with_idx16": quant_bytes_idx,
        "storage_reduction_no_idx": round(dense_bytes / max(quant_bytes, 1), 1),
        "paper_rate": PAPER_RATES["lenet5"],
        "paper_storage_reduction": 3438.0,
    }


def accounted():
    out = {}
    for name, profile in ACCOUNTED_PROFILES.items():
        total = sum(wn for _, wn, _ in profile)
        nnz = sum(int(round(wn * (1.0 - s))) for _, wn, s in profile)
        out[name] = {
            "total_weights": total,
            "nnz": nnz,
            "rate": round(total / nnz, 1),
            "paper_rate": PAPER_RATES[name],
            "per_layer": [
                {"layer": ln, "weights": wn, "sparsity": s} for ln, wn, s in profile
            ],
        }
    return out


def model_file_accounting(path, log):
    model = cadnn_ir.parse_file(path)
    acc = cadnn_ir.accounting_report(model)
    hinted = sum(1 for name in acc["per_layer"] if name in model.sparsity)
    log(
        f"{model.name}: {len(model.nodes)} nodes, "
        f"{acc['total_weights']} weights across {len(acc['per_layer'])} prunable "
        f"layers ({hinted} hinted)"
        + (f", overall rate {acc['rate']}x" if hinted and acc["rate"] else "")
    )
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/compress_report.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--granularity",
        default="element",
        choices=["element", "block", "pattern"],
        help="ADMM projection constraint; the per_layer structure labels "
        "in the report record what each layer actually got",
    )
    ap.add_argument(
        "--model-file",
        default=None,
        help="accounting-only mode: read a .cadnn textual model and report "
        "per-layer pruning from its inline sparsity hints (no training)",
    )
    args = ap.parse_args()
    if args.model_file is not None:
        acc = model_file_accounting(args.model_file, print)
        report = {"model_file": {acc["model"]: acc}}
    else:
        report = {
            "measured": {"lenet5": measured_lenet5(args.quick, print, args.granularity)},
            "accounted": accounted(),
        }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
