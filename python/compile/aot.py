"""AOT lowering: JAX/Pallas models -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact bakes the model's (possibly ADMM-compressed) weights in as
HLO constants — the deployable unit is a model-specific compiled program,
mirroring the paper's compiler-generated mobile kernels. One executable
is emitted per (model, variant, batch): PJRT programs are shape-static,
so the Rust dynamic batcher picks among batch-1/4/8 executables.

Outputs (under artifacts/):
  <model>_<variant>_b<batch>.hlo.txt   HLO text programs
  manifest.json                        model registry for the Rust side
  golden/<entry>.json                  input/output vectors for rust
                                       integration tests

Usage: python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import admm as A
from . import datasets as D
from . import model as M
from . import train as T

BATCHES = (1, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1).

    ``print_large_constants`` is essential: the default printer elides
    weight tensors as ``{...}``, which the Rust-side text parser cannot
    reconstitute — the artifacts bake weights as constants by design.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    po = xc._xla.HloPrintOptions()
    po.print_large_constants = True
    # The xla_extension 0.5.1 parser predates `source_end_line`-style
    # metadata attributes; strip metadata entirely for compatibility.
    po.print_metadata = False
    return comp.as_hlo_module().to_string(po)


def lower_model(apply_fn, params, input_shape, batch, *, masks=None) -> str:
    spec = jax.ShapeDtypeStruct((batch,) + tuple(input_shape), jnp.float32)

    def fwd(x):
        return (apply_fn(params, x, backend="pallas", masks=masks),)

    return to_hlo_text(jax.jit(fwd).lower(spec))


def _train_subject(name, spec, *, quick: bool, log):
    """Brief training so the artifacts are real classifiers, then a
    block-granular ADMM compression pass for the sparse variant."""
    h, w, c = spec["input_shape"]
    # the tiny conv nets need a bigger budget than lenet to reach a
    # respectable accuracy on the 32x32 RGB variant of the task
    # per-model budgets: tinyresnet diverges beyond ~10 epochs at this
    # lr; tinymobilenet underfits below ~14 (see EXPERIMENTS.md notes)
    full_epochs = {"lenet5": 6, "tinyresnet": 8, "tinymobilenet": 14}[name]
    full_n = {"lenet5": 3000, "tinyresnet": 3000, "tinymobilenet": 5000}[name]
    n_train = 600 if quick else full_n
    epochs = 2 if quick else full_epochs
    x, y = D.synthetic_digits(n_train, seed=1, size=h)
    if c == 3:
        x = np.repeat(x, 3, axis=-1)
    xt, yt = D.synthetic_digits(400, seed=2, size=h)
    if c == 3:
        xt = np.repeat(xt, 3, axis=-1)

    fwd = lambda p, xx: spec["apply"](p, xx, backend="ref")
    params = spec["init"](0)
    params, _ = T.train(fwd, params, x, y, epochs=epochs, log=log)
    dense_acc = T.accuracy(fwd, params, xt, yt)
    log(f"{name}: dense acc {dense_acc:.3f}")

    # Block-granular compression (the TPU execution path) at a moderate
    # uniform rate; the aggressive element-wise rates are the separate
    # compress_run.py experiment.
    sparsity = {k: (0.5 if name != "lenet5" else 0.6) for k in spec["prunable"]}
    cfg = A.AdmmConfig(
        sparsity=sparsity,
        granularity="block",
        block=(M.SPARSE_BK, M.SPARSE_BN),
        admm_iters=1 if quick else 3,
        epochs_per_iter=1,
        retrain_epochs=1 if quick else 5,
        # tinymobilenet's ADMM phase diverges at the full training lr
        lr=0.005 if name == "tinymobilenet" else 0.01,
        seed=0,
    )
    res = A.admm_prune(fwd, params, x, y, cfg, log=log)
    sparse_acc = T.accuracy(fwd, res.params, xt, yt)
    log(f"{name}: sparse acc {sparse_acc:.3f} rate {res.overall_rate:.1f}x")
    masks = M.masks_from_params(res.params, spec["prunable"])
    return dict(
        dense_params=params,
        sparse_params=res.params,
        masks=masks,
        dense_acc=dense_acc,
        sparse_acc=sparse_acc,
        test_x=xt,
        test_y=yt,
        rate=res.overall_rate,
    )


def build(out_dir: str, *, quick: bool = False, subjects=None, log=print):
    os.makedirs(out_dir, exist_ok=True)
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)
    subjects = subjects or (
        ["lenet5"] if quick else ["lenet5", "tinyresnet", "tinymobilenet"]
    )
    # partial rebuilds (--subjects) merge into an existing manifest
    manifest = {"format": 1, "models": []}
    man_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(man_path):
        try:
            old = json.load(open(man_path))
            if old.get("format") == 1:
                manifest["models"] = [
                    e for e in old["models"] if e["name"] not in subjects
                ]
        except Exception:
            pass
    batches = (1, 4) if quick else BATCHES

    for name in subjects:
        spec = M.MODELS[name]
        t0 = time.time()
        sub = _train_subject(name, spec, quick=quick, log=log)
        for variant in ("dense", "sparse"):
            params = sub[f"{variant}_params"]
            masks = sub["masks"] if variant == "sparse" else None
            for batch in batches:
                fname = f"{name}_{variant}_b{batch}.hlo.txt"
                hlo = lower_model(
                    spec["apply"], params, spec["input_shape"], batch, masks=masks
                )
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(hlo)
                entry = {
                    "name": name,
                    "variant": variant,
                    "batch": batch,
                    "path": fname,
                    "input_shape": [batch] + list(spec["input_shape"]),
                    "classes": spec["classes"],
                    "accuracy": round(float(sub[f"{variant}_acc"]), 4),
                    "compression_rate": round(float(sub["rate"]), 2)
                    if variant == "sparse"
                    else 1.0,
                }
                manifest["models"].append(entry)
                log(f"  wrote {fname} ({len(hlo)//1024} KiB)")

            # Golden vectors: batch-1 fwd on 4 test images via the SAME
            # pallas path that was lowered — what the artifact must compute.
            gx = jnp.asarray(sub["test_x"][:4])
            glogits = spec["apply"](params, gx, backend="pallas", masks=masks)
            golden = {
                "model": name,
                "variant": variant,
                "input": np.asarray(gx, np.float32).reshape(-1).tolist(),
                "input_shape": list(gx.shape),
                "logits": np.asarray(glogits, np.float32).reshape(-1).tolist(),
                "logits_shape": list(glogits.shape),
                "labels": np.asarray(sub["test_y"][:4]).tolist(),
            }
            with open(
                os.path.join(golden_dir, f"{name}_{variant}.json"), "w"
            ) as f:
                json.dump(golden, f)
        log(f"{name}: done in {time.time() - t0:.0f}s")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest: {len(manifest['models'])} entries")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny build for CI")
    ap.add_argument("--subjects", nargs="*", default=None)
    args = ap.parse_args()
    build(args.out_dir, quick=args.quick, subjects=args.subjects)


if __name__ == "__main__":
    main()
