"""Unified ADMM weight pruning + quantization (paper §3).

The paper extends Zhang et al. (2018a) in three ways, all implemented
here:

1. **ADMM regularization + masked mapping and retraining.** The ADMM
   phase alternates (a) the x-step — DNN training with the dynamic
   quadratic regularizer (rho/2)||W - Z + U||^2, solved with ordinary SGD;
   (b) the z-step — Euclidean projection of (W + U) onto the constraint
   set (top-k magnitude support for pruning; nearest-level for
   quantization), which is the analytical optimum of the second
   sub-problem; (c) the dual update U += W - Z. ADMM alone does not
   guarantee feasibility, so a final *masked mapping* hard-projects W and
   a *masked retraining* phase retrains only the surviving weights
   (gradients masked to the fixed support), restoring accuracy.

2. **Unified pruning + quantization.** The same machinery runs with a
   quantization constraint set (each weight in a 2^bits-level codebook);
   ``compress`` chains pruning then quantization-on-the-support.

3. **Convergence techniques.** ``multi-rho``: rho is multiplied by a
   fixed factor every ADMM iteration (starting small so early iterations
   explore, ending large so W ~= Z); *progressive compression*: the
   target sparsity is reached through a schedule of increasing rates,
   re-running ADMM from the previous solution.

Projection granularity is selectable: ``element`` (the paper's
non-structured pruning, used for the compression-rate accounting and the
CPU/CSR execution path), ``block`` (whole (bk, bn) tiles of the (K, N)
weight view, feeding the BSR execution path and the TPU-adapted
block-sparse kernel — DESIGN.md §Hardware-Adaptation), or ``pattern``
(PatDNN, Niu et al. 2020: each surviving kh x kw kernel keeps one of a
small library of canonical ``entries``-position patterns, and whole
low-energy kernels are *connectivity-pruned*; feeds the Rust
``SparseFormat::Pattern`` execution path — docs/PIPELINE.md walks the
full co-design end to end). The achieved per-layer density of the
structured projections stays within 1% of the request (one tile /
half a pattern of slack), and the exported profile records the
structure label so the Rust planner can pick the matching format.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import train as T


# ------------------------------------------------------------ projections


def project_prune_element(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Euclidean projection onto {at most (1-sparsity)*size non-zeros}:
    keep the largest-magnitude weights, zero the rest. Optimal for the
    l2-proximal z-step (Boyd et al., 2011)."""
    if sparsity <= 0.0:
        return w
    flat = w.reshape(-1)
    keep = max(1, int(round(flat.size * (1.0 - sparsity))))
    if keep >= flat.size:
        return w
    thresh = jnp.sort(jnp.abs(flat))[flat.size - keep]
    return jnp.where(jnp.abs(w) >= thresh, w, 0.0)


def _round_half_up(x: float) -> int:
    """Half-away-from-zero rounding for non-negative x, matching Rust's
    ``f64::round`` — python's banker's ``round`` would cut a different
    support than the native-engine pruners at exact .5 boundaries."""
    return int(np.floor(x + 0.5))


def project_prune_block(
    w: jnp.ndarray, sparsity: float, bk: int, bn: int
) -> jnp.ndarray:
    """Tile-granular projection: rank (bk, bn) tiles of the (K, N) weight
    matrix view by Frobenius norm and keep whole tiles greedily until the
    surviving *element* count reaches ``round(size * (1 - sparsity))``
    (floor of one element: extreme sparsity keeps the single best tile,
    like the element projection, never a zeroed layer). Edge tiles count
    at their true (truncated) size, so the achieved density stays within
    one tile of the request — the Rust planner's BSR cost model consumes
    the profile without fallback."""
    if sparsity <= 0.0:
        return w
    shape = w.shape
    mat = np.asarray(w).reshape(-1, shape[-1])
    k, n = mat.shape
    target = max(1, _round_half_up(mat.size * (1.0 - sparsity)))
    nbk, nbn = -(-k // bk), -(-n // bn)
    # vectorized tile norms (zero-padded edges contribute nothing) and
    # analytic true tile sizes — the z-step runs per layer per ADMM
    # iteration, so no Python-level per-tile loops here
    mp = np.pad(mat.astype(np.float64), ((0, nbk * bk - k), (0, nbn * bn - n)))
    norms = np.sum(mp.reshape(nbk, bk, nbn, bn) ** 2, axis=(1, 3))
    row_sz = np.minimum(bk, k - np.arange(nbk) * bk)
    col_sz = np.minimum(bn, n - np.arange(nbn) * bn)
    sizes = np.outer(row_sz, col_sz).reshape(-1)
    order = np.argsort(-norms.reshape(-1), kind="stable")
    keep = np.zeros(nbk * nbn, dtype=bool)
    kept = 0
    for t in order:
        size = int(sizes[t])
        if kept >= target:
            break
        # the best tile always survives: a nonzero target must not zero
        # the whole layer
        if kept > 0 and kept + size > target and (kept + size - target) > (target - kept):
            break
        keep[t] = True
        kept += size
    mask = np.repeat(np.repeat(keep.reshape(nbk, nbn), bk, axis=0), bn, axis=1)[:k, :n]
    return jnp.asarray((mat * mask).reshape(shape), jnp.asarray(w).dtype)


def select_pattern_library(
    w: jnp.ndarray, entries: int = 4, library_size: int = 8
) -> np.ndarray:
    """Per-layer pattern library selection (PatDNN): every kernel
    nominates its top-``entries`` magnitude positions; the masks with the
    largest accumulated magnitude across kernels form the library.
    ``w`` is HWIO (kh, kw, cin, cout); returns a (lib, kh*kw) bool
    array. Deterministic (ties by position, then mask order)."""
    kh, kw = w.shape[0], w.shape[1]
    kk = kh * kw
    entries = max(1, min(entries, kk))
    mags = np.abs(np.asarray(w)).reshape(kk, -1)  # (positions, kernels)
    nk = mags.shape[1]
    top = np.argsort(-mags, axis=0, kind="stable")[:entries]  # (entries, nk)
    masks = np.zeros((kk, nk), dtype=bool)
    masks[top, np.arange(nk)[None, :]] = True
    scores = np.sum(mags * masks, axis=0)
    # accumulate weight per distinct mask, vectorized: the z-step runs
    # per layer per ADMM iteration, so no per-kernel Python loops (the
    # per-*unique-mask* loop below is bounded by C(kk, entries) <= 126
    # for 3x3/4-entry)
    uniq, inverse = np.unique(masks.T, axis=0, return_inverse=True)  # (u, kk)
    weights = np.bincount(inverse.reshape(-1), weights=scores, minlength=len(uniq))
    keys = [tuple(np.nonzero(row)[0].tolist()) for row in uniq]
    order = sorted(
        range(len(uniq)), key=lambda i: (-float(weights[i]), keys[i])
    )[: max(1, library_size)]
    return uniq[order]


def project_prune_pattern(
    w: jnp.ndarray, sparsity: float, entries: int = 4, library_size: int = 8
) -> jnp.ndarray:
    """PatDNN projection: select the layer's pattern library, snap every
    kernel to its best library pattern, then *connectivity-prune* whole
    kernels (lowest projected magnitude first) until the surviving value
    count lands on ``round(size * (1 - sparsity))`` — within half a
    pattern, i.e. well inside 1% for real layers. Non-conv weights (or
    1x1 kernels) fall back to the element projection. If the requested
    density exceeds ``entries / (kh*kw)`` every kernel survives and the
    density saturates at that ceiling."""
    if sparsity <= 0.0:
        return w
    arr = np.asarray(w)
    if arr.ndim != 4 or arr.shape[0] * arr.shape[1] <= 1:
        return project_prune_element(w, sparsity)
    kh, kw, cin, cout = arr.shape
    kk = kh * kw
    entries = max(1, min(entries, kk))
    lib = select_pattern_library(w, entries, library_size)
    mags = np.abs(arr).reshape(kk, -1).astype(np.float64)  # (kk, nk)
    nk = mags.shape[1]
    lib_scores = lib.astype(np.float64) @ mags  # (lib, nk)
    best = np.argmax(lib_scores, axis=0)  # (nk,)
    best_score = lib_scores[best, np.arange(nk)]
    # floor of one element, half-up rounding: both match the Rust-side
    # pruners so python-exported supports agree with native re-pruning
    target = max(1, _round_half_up(arr.size * (1.0 - sparsity)))
    n_keep = min(nk, max(1, _round_half_up(target / float(entries))))
    keep = np.zeros(nk, dtype=bool)
    if n_keep > 0:
        order = np.argsort(-best_score, kind="stable")
        keep[order[:n_keep]] = True
    final = lib[best].T & keep[None, :]  # (kk, nk)
    mask = final.reshape(arr.shape)
    return jnp.asarray(arr * mask.astype(arr.dtype))


def quant_levels(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """MSE-optimal symmetric uniform codebook step (outliers clip to the
    last level). A max-driven step is so coarse that small surviving
    weights round to zero — accidental extra pruning that destroys
    accuracy; searching the step for minimum reconstruction error is the
    true Euclidean projection onto the best codebook of this family,
    matching the ADMM z-step's optimality requirement."""
    flat = w.reshape(-1)
    nzmask = flat != 0.0
    amax = float(jnp.maximum(jnp.max(jnp.abs(flat)), 1e-8))
    n = 2 ** (bits - 1) - 1  # e.g. bits=4 -> levels -7..7 scaled
    best_step, best_err = amax / n, None
    for f in np.linspace(0.05, 1.0, 39):
        step = amax * f / n
        q = jnp.clip(jnp.round(flat / step), -n, n) * step
        err = float(jnp.sum(jnp.where(nzmask, (flat - q) ** 2, 0.0)))
        if best_err is None or err < best_err:
            best_err, best_step = err, step
    return jnp.asarray(best_step, w.dtype)


def project_quantize(w: jnp.ndarray, bits: int, preserve_zero: bool = True):
    """Euclidean projection onto the quantized-codebook constraint set:
    round each weight to the nearest level. Zeros stay zero so the pruning
    support survives. Returns (projected, step)."""
    step = quant_levels(w, bits)
    n = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(w / step), -n, n) * step
    if preserve_zero:
        q = jnp.where(w == 0.0, 0.0, q)
    return q, step


# ----------------------------------------------------------- ADMM config


@dataclass
class AdmmConfig:
    """Hyper-parameters of one ADMM compression run."""

    sparsity: Dict[str, float]  # layer name -> target sparsity in [0,1)
    rho: float = 1e-3
    rho_factor: float = 1.6  # multi-rho: rho *= factor per ADMM iteration
    admm_iters: int = 6
    epochs_per_iter: int = 2
    retrain_epochs: int = 4
    lr: float = 0.01
    batch: int = 64
    granularity: str = "element"  # "element" | "block" | "pattern"
    # (bk, bn) tiles for "block". The default matches the TPU pallas
    # kernel's SPARSE_BK/SPARSE_BN (model.py); pass (4, 4) to target the
    # Rust BSR candidates instead (a 16x16-aligned support is also
    # 4x4-aligned, so either feeds the native planner).
    block: Tuple[int, int] = (16, 16)
    pattern_entries: int = 4  # surviving positions per kernel ("pattern")
    pattern_library: int = 8  # canonical patterns per layer ("pattern")
    quant_bits: Optional[int] = None  # unified prune+quantize when set
    progressive_stages: Sequence[float] = field(default_factory=lambda: (1.0,))
    # each stage scales the per-layer sparsity: e.g. (0.6, 1.0) reaches the
    # target in two progressive rounds (paper's progressive compression).
    seed: int = 0


@dataclass
class CompressResult:
    params: dict
    masks: Dict[str, jnp.ndarray]  # element masks over "w"
    history: list
    per_layer_nnz: Dict[str, Tuple[int, int]]  # name -> (nnz, total)
    quant_bits: Optional[int] = None
    # name -> achieved structure label ("element" | "block{bk}x{bn}" |
    # "pattern{entries}"); exported into compress_report.json so the Rust
    # planner (SparsityProfile::from_report) knows which format to plan.
    structures: Dict[str, str] = field(default_factory=dict)

    @property
    def overall_rate(self) -> float:
        nnz = sum(v[0] for v in self.per_layer_nnz.values())
        tot = sum(v[1] for v in self.per_layer_nnz.values())
        return tot / max(nnz, 1)


def _project(w, sparsity, cfg: AdmmConfig):
    if cfg.granularity == "block":
        return project_prune_block(w, sparsity, *cfg.block)
    if cfg.granularity == "pattern":
        return project_prune_pattern(
            w, sparsity, cfg.pattern_entries, cfg.pattern_library
        )
    return project_prune_element(w, sparsity)


def _structure_label(w, cfg: AdmmConfig) -> str:
    """The structure a layer's support actually has after `_project`
    (pattern degrades to element on non-conv / 1x1 weights)."""
    if cfg.granularity == "block":
        return f"block{cfg.block[0]}x{cfg.block[1]}"
    if cfg.granularity == "pattern":
        arr = np.asarray(w)
        if arr.ndim == 4 and arr.shape[0] * arr.shape[1] > 1:
            return f"pattern{cfg.pattern_entries}"
    return "element"


def admm_prune(
    apply_fn: Callable,
    params: dict,
    x,
    y,
    cfg: AdmmConfig,
    log: Optional[Callable[[str], None]] = None,
) -> CompressResult:
    """Full pipeline: progressive( ADMM-regularized training -> masked
    mapping -> masked retraining ) [-> quantization-on-support]."""
    log = log or (lambda s: None)
    history: list = []

    for stage_i, stage in enumerate(cfg.progressive_stages):
        targets = {k: s * stage for k, s in cfg.sparsity.items()}
        log(f"[stage {stage_i}] targets={ {k: round(v, 4) for k, v in targets.items()} }")

        # --- ADMM regularization phase ------------------------------
        Z = {k: _project(params[k]["w"], targets[k], cfg) for k in targets}
        U = {k: jnp.zeros_like(params[k]["w"]) for k in targets}
        rho = cfg.rho
        for it in range(cfg.admm_iters):
            rho_now = rho  # captured by the closure below

            def prox(p, _Z=Z, _U=U, _rho=rho_now):
                # (rho/2) sum_l ||W_l - Z_l + U_l||^2 — the q1 quadratic
                # of the first sub-problem.
                terms = [
                    jnp.sum((p[k]["w"] - _Z[k] + _U[k]) ** 2) for k in _Z
                ]
                return 0.5 * _rho * sum(terms)

            params, hist = T.train(
                apply_fn, params, x, y,
                epochs=cfg.epochs_per_iter, batch=cfg.batch, lr=cfg.lr,
                seed=cfg.seed + it, loss_extra=prox,
            )
            history.extend(hist)
            # z-step: analytical Euclidean projection; u-step: dual ascent.
            Z = {k: _project(params[k]["w"] + U[k], targets[k], cfg) for k in Z}
            U = {k: U[k] + params[k]["w"] - Z[k] for k in U}
            gap = float(
                sum(jnp.sum((params[k]["w"] - Z[k]) ** 2) for k in Z)
            )
            log(f"[stage {stage_i}] admm iter {it}: rho={rho:.2e} ||W-Z||^2={gap:.4e}")
            rho *= cfg.rho_factor  # multi-rho schedule

        # --- masked mapping (feasibility guarantee) ------------------
        masks = {}
        for k in targets:
            pruned = _project(params[k]["w"], targets[k], cfg)
            masks[k] = (pruned != 0.0).astype(jnp.float32)
            params[k]["w"] = pruned

        # --- masked retraining ---------------------------------------
        params, hist = T.train(
            apply_fn, params, x, y,
            epochs=cfg.retrain_epochs, batch=cfg.batch, lr=cfg.lr * 0.5,
            seed=cfg.seed + 100 + stage_i, weight_masks=masks,
        )
        history.extend(hist)

    # --- unified quantization on the pruned support -------------------
    # Alternating projection / masked retraining (a straight-through-
    # style relaxation): each round projects onto the codebook, then
    # lets masked SGD repair the damage; the LAST step is a projection,
    # so the constraint holds exactly on exit.
    if cfg.quant_bits is not None:
        rounds = max(1, cfg.retrain_epochs // 2)
        for r in range(rounds):
            for k in cfg.sparsity:
                q, _ = project_quantize(params[k]["w"], cfg.quant_bits)
                params[k]["w"] = q * masks[k]
            if r == rounds - 1:
                break
            params, hist = T.train(
                apply_fn, params, x, y,
                epochs=2, batch=cfg.batch, lr=cfg.lr * 0.25,
                seed=cfg.seed + 999 + r, weight_masks=masks,
            )
            history.extend(hist)
        # final recovery: quantized layers frozen (all-zero update mask),
        # everything else (biases, unconstrained layers) adapts to the
        # quantized weights — constraints stay exactly satisfied.
        freeze = {k: jnp.zeros_like(masks[k]) for k in cfg.sparsity}
        params, hist = T.train(
            apply_fn, params, x, y,
            epochs=2, batch=cfg.batch, lr=cfg.lr * 0.5,
            seed=cfg.seed + 1999, weight_masks=freeze,
        )
        history.extend(hist)

    per_layer = {}
    structures = {}
    for k in cfg.sparsity:
        w = params[k]["w"]
        per_layer[k] = (int(jnp.sum(w != 0.0)), int(w.size))
        structures[k] = _structure_label(w, cfg)
    return CompressResult(
        params=params,
        masks=masks,
        history=history,
        per_layer_nnz=per_layer,
        quant_bits=cfg.quant_bits,
        structures=structures,
    )


def quantize_on_support(
    apply_fn: Callable,
    params: dict,
    masks: Dict[str, jnp.ndarray],
    x,
    y,
    bits: int,
    *,
    rounds: int = 4,
    epochs_per_round: int = 2,
    lr: float = 0.0025,
    batch: int = 64,
    seed: int = 0,
    log: Optional[Callable[[str], None]] = None,
) -> dict:
    """Quantize already-pruned params WITHOUT touching the support:
    alternating codebook-projection / masked retraining, then a final
    projection followed by frozen-weight recovery of the unconstrained
    parameters. This is the §3 'unified framework' second phase run
    standalone (re-running the prune phase would churn the support)."""
    log = log or (lambda s: None)
    for r in range(rounds):
        for k in masks:
            q, _ = project_quantize(params[k]["w"], bits)
            params[k]["w"] = q * masks[k]
        if r == rounds - 1:
            break
        params, _ = T.train(
            apply_fn, params, x, y,
            epochs=epochs_per_round, batch=batch, lr=lr,
            seed=seed + r, weight_masks=masks,
        )
    freeze = {k: jnp.zeros_like(masks[k]) for k in masks}
    params, _ = T.train(
        apply_fn, params, x, y,
        epochs=2 * epochs_per_round, batch=batch, lr=lr * 2,
        seed=seed + 777, weight_masks=freeze,
    )
    return params


def codebook_of(w, bits: int) -> np.ndarray:
    """Distinct nonzero values of a quantized weight tensor, ascending —
    the codebook the Rust side's quantized sparse payloads reconstruct
    from (``compress::qsparse``). After ``project_quantize`` /
    ``quantize_on_support`` the distinct nonzero count is at most
    ``2^bits - 1`` (zero is the reserved support level and is never in
    the codebook); the export asserts that invariant rather than
    silently shipping an over-wide table."""
    arr = np.asarray(w)
    vals = np.unique(arr[arr != 0.0])
    assert len(vals) <= 2**bits - 1, (
        f"{len(vals)} distinct nonzero levels exceed the {bits}-bit codebook"
    )
    return vals


def export_quant(params: dict, layers, bits: int) -> dict:
    """Per-layer ``{"bits", "codebook"}`` export for compress_report.json
    (the step docs/PIPELINE.md documents): what
    ``SparsityProfile::from_report`` parses to drive the planner's
    ``ValuePolicy::Auto`` onto quantized payloads."""
    return {
        k: {
            "bits": bits,
            "codebook": [float(v) for v in codebook_of(params[k]["w"], bits)],
        }
        for k in layers
    }


# ------------------------------------------------- storage accounting


def storage_bytes_dense(total_weights: int, bits: int = 32) -> int:
    return total_weights * bits // 8


def storage_bytes_compressed(
    nnz: int, bits_per_weight: int, index_bits: int = 0
) -> int:
    """Paper's storage accounting: §3 quotes 3,438x 'not accounting for
    indices', i.e. index_bits=0; we report both."""
    return (nnz * (bits_per_weight + index_bits) + 7) // 8
