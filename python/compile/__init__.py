"""CADNN build-time Python: Layer-1 Pallas kernels, Layer-2 JAX models,
ADMM compression, and the AOT lowering pipeline. Never imported at runtime."""
