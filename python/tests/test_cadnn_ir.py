"""Tests for the pure-python `.cadnn` reader (compile/cadnn_ir.py).

Pins the golden models/*.cadnn files against the canonical parameter
counts the Rust model builders pin, so the python and Rust front-ends
cannot drift apart silently.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parents[1]))
from compile import cadnn_ir as C  # noqa: E402

MODELS = Path(__file__).parents[2] / "models"

TINY = """\
model tiny
input input [1,8,8,3]
c1 = conv2d(input) k=3 cout=8 stride=1 pad=1 sparsity=0.5
b1 = batchnorm(c1)
r1 = relu(b1)
p1 = maxpool(r1) k=2
gap = global_avg_pool(p1)
fc = dense(gap) cout=10 bias sparsity=0.8 prune=block4x4 quant=4
out = softmax(fc)
output out
"""


def test_parses_tiny_model():
    m = C.parse(TINY)
    assert m.name == "tiny"
    assert [nd.name for nd in m.nodes[:3]] == ["input", "c1", "b1"]
    assert m.nodes[1].shape == [1, 8, 8, 8]
    assert m.nodes[4].shape == [1, 4, 4, 8]
    assert m.nodes[-1].shape == [1, 10]
    assert m.nodes[m.output].name == "out"
    assert m.nodes[1].weight_count == 3 * 3 * 3 * 8
    assert m.nodes[6].weight_count == 80 and m.nodes[6].aux_params == 10


def test_hints_become_profile_entries():
    m = C.parse(TINY)
    assert m.sparsity == {"c1": 0.5, "fc": 0.8}
    assert m.structures == {"fc": "block4x4"}
    assert m.quant == {"fc": 4}


def test_accounting_report_uses_node_names():
    acc = C.accounting_report(C.parse(TINY))
    assert set(acc["per_layer"]) == {"c1", "fc"}
    c1 = acc["per_layer"]["c1"]
    assert c1["total"] == 216 and c1["nnz"] == 108
    fc = acc["per_layer"]["fc"]
    assert fc["structure"] == "block4x4" and fc["quant"] == 4


GOLDEN_PINS = {
    # name -> (exact params or (lo, hi), weight layers, final shape)
    "lenet5": (61_706, 5, [1, 10]),
    "mobilenet_v1": ((4_200_000, 4_280_000), 28, [1, 1000]),
    "resnet50": (25_610_152, 54, [1, 1000]),
    "inception_v3": ((23_600_000, 24_000_000), 95, [1, 1000]),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_PINS))
def test_golden_files_parse_with_pinned_accounting(name):
    m = C.parse_file(MODELS / f"{name}.cadnn")
    assert m.name == name
    names = [nd.name for nd in m.nodes]
    assert len(names) == len(set(names))
    params = sum(nd.weight_count + nd.aux_params for nd in m.nodes)
    pin, weight_layers, final = GOLDEN_PINS[name]
    if isinstance(pin, tuple):
        assert pin[0] <= params <= pin[1], params
    else:
        assert params == pin
    assert sum(1 for nd in m.nodes if nd.weight_count > 0) == weight_layers
    assert m.nodes[m.output].shape == final


def test_resnet50_golden_shape_pins():
    m = C.parse_file(MODELS / "resnet50.cadnn")
    shapes = {nd.name: nd.shape for nd in m.nodes}
    assert shapes["maxpool"] == [1, 56, 56, 64]
    assert shapes["s0b2_out"] == [1, 56, 56, 256]
    assert shapes["s3b2_out"] == [1, 7, 7, 2048]


def test_inception_golden_grid_pins():
    m = C.parse_file(MODELS / "inception_v3.cadnn")
    shapes = {nd.name: nd.shape for nd in m.nodes}
    assert shapes["mixed2_cat"] == [1, 35, 35, 288]
    assert shapes["mixed3_cat"] == [1, 17, 17, 768]
    assert shapes["mixed8_cat"] == [1, 8, 8, 1280]
    assert shapes["mixed10_cat"] == [1, 8, 8, 2048]


MALFORMED = [
    ("", "expected 'model"),
    ("model t\n", "expected 'input"),
    ("model t\ninput x [0]\n", "dimension must be"),
    ("model t\ninput x [1,4,4,2]\na = add(x, y)\n", "unknown input 'y'"),
    ("model t\ninput x [1,4,4,2]\nx = relu(x)\n", "duplicate node name"),
    ("model t\ninput x [1,4,4,2]\nc = conv2d(x) k=9 cout=4\n", "does not fit"),
    ("model t\ninput x [1,4,4,2]\nd = dense(x) cout=4\n", "rank-2"),
    ("model t\ninput x [1,4,4,2]\nr = relu(x) bogus=1\n", "unknown attribute"),
    ("model t\ninput x [1,4,4,2]\nr = relu(x) sparsity=0.5\n", "weight layers"),
    ("model t\ninput x [1,4,4,2]\noutput y\n", "unknown node"),
    ("model t\ninput x [1,4,4,2]\noutput x\nr = relu(x)\n", "last statement"),
    ("model t\ninput x [1,4,4,2]\nc = convv2d(x) k=3\n", "unknown op"),
    ("a @ b", "unexpected character"),
]


@pytest.mark.parametrize("src,frag", MALFORMED)
def test_malformed_input_raises_positioned_errors(src, frag):
    with pytest.raises(C.ParseError) as e:
        C.parse(src)
    assert frag in str(e.value)
    assert "parse error at" in str(e.value)


def test_error_positions_are_exact():
    with pytest.raises(C.ParseError) as e:
        C.parse("model t\ninput x [1,8,8,3]\nc = convv2d(x) k=3 cout=8\n")
    err = e.value
    assert (err.line, err.col, err.token) == (3, 5, "convv2d")


def test_truncation_never_crashes_differently():
    src = TINY
    for cut in range(len(src)):
        try:
            C.parse(src[:cut])
        except C.ParseError:
            pass  # only ParseError is acceptable
