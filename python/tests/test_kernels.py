"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/strides/paddings/sparsity; fixed-seed cases pin
the exact configurations the AOT models use.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    conv2d_fused,
    depthwise_fused,
    gemm,
    gemm_bn_relu,
    ref,
    sparse_gemm,
    sparse_gemm_bn_relu,
)
from compile.kernels.conv_fused import conv1x1_as_gemm, conv2d_sparse_fused, im2col
from compile.kernels.sparse_gemm import tile_mask_from_weights
from compile.kernels.common import pick_block, round_up

RTOL = 2e-4
ATOL = 2e-4


def _arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------- gemm

dims = st.integers(min_value=1, max_value=70)


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, (m, k)), _arr(rng, (k, n))
    np.testing.assert_allclose(gemm(x, y), ref.gemm(x, y), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_gemm_bn_relu_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, (m, k)), _arr(rng, (k, n))
    s, h = _arr(rng, (n,)), _arr(rng, (n,))
    np.testing.assert_allclose(
        gemm_bn_relu(x, y, s, h), ref.gemm_bn_relu(x, y, s, h), rtol=RTOL, atol=ATOL
    )


def test_gemm_identity():
    x = jnp.eye(33, dtype=jnp.float32)
    y = jnp.arange(33 * 17, dtype=jnp.float32).reshape(33, 17)
    np.testing.assert_allclose(gemm(x, y), y, rtol=RTOL, atol=ATOL)


def test_gemm_explicit_blocks():
    # Block sizes that do NOT divide the dims: exercises the padding path.
    rng = np.random.default_rng(7)
    x, y = _arr(rng, (130, 257)), _arr(rng, (257, 65))
    out = gemm(x, y, bm=64, bn=32, bk=128)
    np.testing.assert_allclose(out, ref.gemm(x, y), rtol=RTOL, atol=ATOL)


def test_gemm_relu_clamps_negative():
    x = -jnp.ones((4, 4), jnp.float32)
    y = jnp.ones((4, 4), jnp.float32)
    s = jnp.ones((4,), jnp.float32)
    h = jnp.zeros((4,), jnp.float32)
    out = gemm_bn_relu(x, y, s, h)
    assert jnp.all(out == 0.0)


# --------------------------------------------------------- sparse gemm


@settings(max_examples=10, deadline=None)
@given(
    m=dims,
    k=dims,
    n=dims,
    bk=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_gemm_matches_ref(m, k, n, bk, bn, density, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, (m, k)), _arr(rng, (k, n))
    nk, nn = math.ceil(k / bk), math.ceil(n / bn)
    mask = jnp.asarray(rng.random((nk, nn)) < density, jnp.int32)
    np.testing.assert_allclose(
        sparse_gemm(x, y, mask, bk=bk, bn=bn),
        ref.sparse_gemm(x, y, mask, bk, bn),
        rtol=RTOL,
        atol=ATOL,
    )


@settings(max_examples=8, deadline=None)
@given(
    m=dims,
    k=dims,
    n=dims,
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_gemm_bn_relu_matches_ref(m, k, n, density, seed):
    bk = bn = 16
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, (m, k)), _arr(rng, (k, n))
    s, h = _arr(rng, (n,)), _arr(rng, (n,))
    mask = jnp.asarray(
        rng.random((math.ceil(k / bk), math.ceil(n / bn))) < density, jnp.int32
    )
    np.testing.assert_allclose(
        sparse_gemm_bn_relu(x, y, mask, s, h, bk=bk, bn=bn),
        ref.sparse_gemm_bn_relu(x, y, mask, s, h, bk, bn),
        rtol=RTOL,
        atol=ATOL,
    )


def test_sparse_gemm_all_zero_mask_gives_zero():
    rng = np.random.default_rng(1)
    x, y = _arr(rng, (20, 32)), _arr(rng, (32, 24))
    mask = jnp.zeros((2, 2), jnp.int32)
    out = sparse_gemm(x, y, mask, bk=16, bn=16)
    assert jnp.all(out == 0.0)


def test_sparse_gemm_full_mask_equals_dense():
    rng = np.random.default_rng(2)
    x, y = _arr(rng, (20, 32)), _arr(rng, (32, 24))
    mask = jnp.ones((2, 2), jnp.int32)
    np.testing.assert_allclose(
        sparse_gemm(x, y, mask, bk=16, bn=16), ref.gemm(x, y), rtol=RTOL, atol=ATOL
    )


def test_tile_mask_from_weights():
    y = np.zeros((32, 32), np.float32)
    y[0, 0] = 1.0   # tile (0, 0) live
    y[20, 25] = 2.0  # tile (1, 1) live
    mask = tile_mask_from_weights(jnp.asarray(y), 16, 16)
    np.testing.assert_array_equal(np.asarray(mask), [[1, 0], [0, 1]])


def test_sparse_gemm_consistent_with_derived_mask():
    """Pruned weights + derived tile mask == dense matmul on pruned weights."""
    rng = np.random.default_rng(3)
    y = np.array(_arr(rng, (48, 48)))
    y[y < 0.5] = 0.0  # heavy pruning
    y = jnp.asarray(y)
    x = _arr(rng, (10, 48))
    mask = tile_mask_from_weights(y, 16, 16)
    np.testing.assert_allclose(
        sparse_gemm(x, y, mask, bk=16, bn=16), ref.gemm(x, y), rtol=RTOL, atol=ATOL
    )


# ---------------------------------------------------------------- conv

small = st.integers(min_value=3, max_value=14)
chan = st.integers(min_value=1, max_value=12)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    h=small,
    cin=chan,
    cout=chan,
    ksp=st.sampled_from([(1, 1, 0), (3, 1, 1), (3, 2, 1), (5, 1, 2), (5, 2, 2), (3, 1, 0)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_fused_matches_ref(n, h, cin, cout, ksp, seed):
    kh, stride, padding = ksp
    if h + 2 * padding < kh:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n, h, h, cin))
    w = _arr(rng, (kh, kh, cin, cout))
    s, b = _arr(rng, (cout,)), _arr(rng, (cout,))
    np.testing.assert_allclose(
        conv2d_fused(x, w, s, b, stride=stride, padding=padding),
        ref.conv2d_fused(x, w, s, b, stride, padding),
        rtol=5e-4,
        atol=5e-4,
    )


def test_conv2d_fused_no_relu():
    rng = np.random.default_rng(11)
    x = _arr(rng, (1, 8, 8, 3))
    w = _arr(rng, (3, 3, 3, 6))
    s, b = _arr(rng, (6,)), _arr(rng, (6,))
    np.testing.assert_allclose(
        conv2d_fused(x, w, s, b, stride=1, padding=1, relu=False),
        ref.conv2d_fused(x, w, s, b, 1, 1, relu=False),
        rtol=5e-4,
        atol=5e-4,
    )


def test_conv1x1_as_gemm_equals_conv():
    """The paper's 1x1->GEMM transformation is exact."""
    rng = np.random.default_rng(12)
    x = _arr(rng, (2, 7, 7, 9))
    w = _arr(rng, (1, 1, 9, 13))
    np.testing.assert_allclose(
        conv1x1_as_gemm(x, w), ref.conv2d(x, w), rtol=5e-4, atol=5e-4
    )


def test_conv2d_sparse_fused_matches_masked_ref():
    rng = np.random.default_rng(13)
    x = _arr(rng, (1, 8, 8, 4))
    w = np.array(_arr(rng, (3, 3, 4, 8)))
    # Prune, then derive the tile mask exactly as the compressor does.
    w[np.abs(w) < 0.7] = 0.0
    w = jnp.asarray(w)
    wmat = w.reshape(36, 8)
    mask = tile_mask_from_weights(wmat, 16, 8)
    s, b = _arr(rng, (8,)), _arr(rng, (8,))
    np.testing.assert_allclose(
        conv2d_sparse_fused(x, w, mask, s, b, stride=1, padding=1, bk=16, bn=8),
        ref.conv2d_fused(x, w, s, b, 1, 1),
        rtol=5e-4,
        atol=5e-4,
    )


@settings(max_examples=8, deadline=None)
@given(
    h=small,
    c=chan,
    ksp=st.sampled_from([(3, 1, 1), (3, 2, 1), (1, 1, 0)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_times_weights_equals_conv(h, c, ksp, seed):
    """im2col is a pure layout transformation: patches @ W == conv."""
    kh, stride, padding = ksp
    if h + 2 * padding < kh:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (2, h, h, c))
    w = _arr(rng, (kh, kh, c, 5))
    patches, (n, ho, wo) = im2col(x, kh, kh, stride, padding)
    out = (patches @ w.reshape(-1, 5)).reshape(n, ho, wo, 5)
    np.testing.assert_allclose(out, ref.conv2d(x, w, stride, padding), rtol=5e-4, atol=5e-4)


# ----------------------------------------------------------- depthwise


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2),
    h=small,
    c=st.integers(1, 16),
    ksp=st.sampled_from([(3, 1, 1), (3, 2, 1), (5, 1, 2)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_fused_matches_ref(n, h, c, ksp, seed):
    kh, stride, padding = ksp
    if h + 2 * padding < kh:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n, h, h, c))
    w = _arr(rng, (kh, kh, c))
    s, b = _arr(rng, (c,)), _arr(rng, (c,))
    np.testing.assert_allclose(
        depthwise_fused(x, w, s, b, stride=stride, padding=padding),
        ref.depthwise_fused(x, w, s, b, stride, padding),
        rtol=5e-4,
        atol=5e-4,
    )


def test_depthwise_channel_block_padding():
    """Channel count not a multiple of the block: padding path."""
    rng = np.random.default_rng(21)
    x = _arr(rng, (1, 6, 6, 5))
    w = _arr(rng, (3, 3, 5))
    s, b = _arr(rng, (5,)), _arr(rng, (5,))
    np.testing.assert_allclose(
        depthwise_fused(x, w, s, b, stride=1, padding=1, bc=4),
        ref.depthwise_fused(x, w, s, b, 1, 1),
        rtol=5e-4,
        atol=5e-4,
    )


# ------------------------------------------------------------- helpers


@given(st.integers(1, 10_000), st.sampled_from([1, 2, 8, 16, 128]))
def test_round_up(x, m):
    r = round_up(x, m)
    assert r >= x and r % m == 0 and r - x < m


@given(st.integers(1, 4096))
def test_pick_block_divides_padded(dim):
    b = pick_block(dim, 128)
    assert b >= 1
    assert round_up(dim, b) % b == 0
    assert b <= 128 or b < 2 * dim
