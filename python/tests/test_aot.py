"""AOT pipeline: HLO text format invariants + manifest round-trip.

The expensive end-to-end check (rust loads the artifact and reproduces the
golden logits) lives in rust/tests/artifact_roundtrip.rs; here we verify
the python half: the text the 0.5.1 parser must accept.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def lenet_hlo():
    p = M.lenet5_init(0)
    return aot.lower_model(M.lenet5_apply, p, (28, 28, 1), 1)


def test_hlo_has_full_constants(lenet_hlo):
    assert "{...}" not in lenet_hlo, "large constants were elided"


def test_hlo_has_no_metadata(lenet_hlo):
    # xla_extension 0.5.1's parser rejects source_end_line et al.
    assert "metadata=" not in lenet_hlo
    assert "source_end_line" not in lenet_hlo


def test_hlo_is_entry_module(lenet_hlo):
    assert lenet_hlo.startswith("HloModule")
    assert "ENTRY" in lenet_hlo


def test_hlo_single_param_tuple_root(lenet_hlo):
    """One parameter (the image batch); weights are baked constants; the
    root is a tuple (return_tuple=True) the rust side unwraps."""
    entry = lenet_hlo[lenet_hlo.index("ENTRY") :]
    first_line = entry.splitlines()[0]
    assert first_line.count("f32[1,28,28,1]") == 1
    assert "(f32[1,10])" in first_line  # tuple-wrapped logits


def test_batch_variants_differ_only_in_batch():
    p = M.lenet5_init(0)
    h1 = aot.lower_model(M.lenet5_apply, p, (28, 28, 1), 1)
    h4 = aot.lower_model(M.lenet5_apply, p, (28, 28, 1), 4)
    assert "f32[1,28,28,1]" in h1 and "f32[4,28,28,1]" in h4


def test_build_quick_manifest(tmp_path):
    """Whole quick build: manifest schema + files exist + goldens coherent."""
    aot.build(str(tmp_path), quick=True, log=lambda s: None)
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["format"] == 1
    assert len(man["models"]) == 4  # lenet5 x {dense,sparse} x {b1,b4}
    for entry in man["models"]:
        path = tmp_path / entry["path"]
        assert path.exists() and path.stat().st_size > 10_000
        assert entry["input_shape"][0] == entry["batch"]
        assert entry["classes"] == 10
        assert 0.0 <= entry["accuracy"] <= 1.0
    for variant in ("dense", "sparse"):
        g = json.load(open(tmp_path / "golden" / f"lenet5_{variant}.json"))
        n = int(np.prod(g["input_shape"]))
        assert len(g["input"]) == n
        assert len(g["logits"]) == int(np.prod(g["logits_shape"]))
        assert g["logits_shape"][1] == 10
    # sparse variant records a real compression rate
    sparse = [m for m in man["models"] if m["variant"] == "sparse"]
    assert all(m["compression_rate"] > 1.5 for m in sparse)
