"""L2 model zoo: shapes, backend equivalence, sparse-path consistency."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.sparse_gemm import tile_mask_from_weights


@pytest.mark.parametrize("name", list(M.MODELS))
@pytest.mark.parametrize("batch", [1, 3])
def test_output_shape(name, batch):
    spec = M.MODELS[name]
    p = spec["init"](0)
    x = jnp.zeros((batch,) + spec["input_shape"], jnp.float32)
    out = spec["apply"](p, x, backend="ref")
    assert out.shape == (batch, spec["classes"])


@pytest.mark.parametrize("name", list(M.MODELS))
def test_backend_equivalence(name):
    """The architecture-aware pallas path computes the same function as the
    plain jnp reference path — the paper's transformations are
    semantics-preserving."""
    spec = M.MODELS[name]
    p = spec["init"](3)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2,) + spec["input_shape"]), jnp.float32)
    a = spec["apply"](p, x, backend="ref")
    b = spec["apply"](p, x, backend="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_sparse_path_matches_ref_on_pruned_weights(name):
    """Prune weights tile-wise, derive masks, run the block-sparse pallas
    path; must equal the ref path on the pruned params."""
    from compile import admm as A

    spec = M.MODELS[name]
    p = spec["init"](5)
    for lname in spec["prunable"]:
        p[lname]["w"] = A.project_prune_block(
            p[lname]["w"], 0.5, M.SPARSE_BK, M.SPARSE_BN
        )
    masks = M.masks_from_params(p, spec["prunable"])
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2,) + spec["input_shape"]), jnp.float32)
    a = spec["apply"](p, x, backend="ref")
    b = spec["apply"](p, x, backend="pallas", masks=masks)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_weight_matrix_views():
    p = M.lenet5_init(0)
    assert M.weight_matrix(p["c1"]).shape == (25, 6)
    assert M.weight_matrix(p["c2"]).shape == (150, 16)
    assert M.weight_matrix(p["f1"]).shape == (400, 120)


def test_masks_from_params_shapes():
    p = M.lenet5_init(0)
    masks = M.masks_from_params(p, M.LENET5_PRUNABLE)
    wm = M.weight_matrix(p["f1"])
    mk = masks["f1"]
    assert mk.shape == (-(-wm.shape[0] // M.SPARSE_BK), -(-wm.shape[1] // M.SPARSE_BN))
    # unpruned weights -> all tiles live
    assert int(jnp.sum(mk)) == mk.size


def test_bn_fold_identity():
    """BN with gamma=1,beta=0,mean=0,var=1 is the identity affine."""
    from compile.model import _fold_bn

    scale, shift = _fold_bn(
        jnp.ones(4), jnp.zeros(4), jnp.zeros(4), jnp.ones(4) - 1e-5
    )
    np.testing.assert_allclose(scale, jnp.ones(4), rtol=1e-4)
    np.testing.assert_allclose(shift, jnp.zeros(4), atol=1e-6)


def test_lenet5_gradients_flow():
    """Every parameter receives a nonzero gradient through the ref path."""
    import jax

    p = M.lenet5_init(0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 28, 28, 1)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3])

    def loss(pp):
        logits = M.lenet5_apply(pp, x, backend="ref")
        return -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
        )

    g = jax.grad(loss)(p)
    for lname, lp in g.items():
        assert float(jnp.sum(jnp.abs(lp["w"]))) > 0.0, f"dead grad in {lname}"
