"""ADMM compression framework: projection optimality, feasibility,
convergence machinery, storage accounting."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import admm as A
from compile import datasets as D
from compile import model as M
from compile import train as T


# ----------------------------------------------------------- projections


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 400),
    sparsity=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_element_projection_feasible(n, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    z = A.project_prune_element(w, sparsity)
    keep = max(1, int(round(n * (1.0 - sparsity))))
    assert int(jnp.sum(z != 0)) <= max(keep, int(jnp.sum(jnp.abs(w) == jnp.abs(w).max())) * keep)
    # kept entries are untouched
    nz = np.asarray(z != 0)
    np.testing.assert_array_equal(np.asarray(z)[nz], np.asarray(w)[nz])


def test_element_projection_keeps_largest():
    w = jnp.asarray([0.1, -3.0, 0.5, 2.0, -0.05], jnp.float32)
    z = A.project_prune_element(w, 0.6)  # keep 2
    np.testing.assert_allclose(np.asarray(z), [0.0, -3.0, 0.0, 2.0, 0.0])


def test_element_projection_is_euclidean_optimal():
    """Among all vectors with the same support size, the magnitude-top-k
    projection minimizes ||w - z||_2 — check against brute force."""
    import itertools

    rng = np.random.default_rng(0)
    w = rng.normal(size=6).astype(np.float32)
    keep = 2
    z = np.asarray(A.project_prune_element(jnp.asarray(w), 1.0 - keep / 6))
    best = None
    for support in itertools.combinations(range(6), keep):
        cand = np.zeros(6, np.float32)
        for i in support:
            cand[i] = w[i]
        d = np.sum((w - cand) ** 2)
        best = d if best is None else min(best, d)
    assert np.isclose(np.sum((w - z) ** 2), best, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(4, 60),
    n=st.integers(4, 60),
    sparsity=st.floats(0.0, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_projection_zeroes_whole_tiles(k, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    z = A.project_prune_block(w, sparsity, 16, 16)
    # every 16x16 tile is either all-zero or identical to w's tile
    zk = np.asarray(z)
    wk = np.asarray(w)
    for i in range(0, k, 16):
        for j in range(0, n, 16):
            tz = zk[i : i + 16, j : j + 16]
            tw = wk[i : i + 16, j : j + 16]
            assert (tz == 0).all() or np.array_equal(tz, tw)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(8, 64),
    n=st.integers(8, 64),
    sparsity=st.floats(0.3, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_projection_density_within_one_tile(k, n, sparsity, seed):
    """The rewritten greedy keep targets the *element* count: achieved
    nnz lands within one (4, 4) tile of round(size * (1 - sparsity)),
    and never zeroes the whole layer."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    z = A.project_prune_block(w, sparsity, 4, 4)
    nnz = int(jnp.sum(z != 0))
    target = max(1, int(np.floor(k * n * (1.0 - sparsity) + 0.5)))
    assert abs(nnz - target) <= 16, (nnz, target)
    assert nnz > 0


def test_block_projection_keeps_best_tile_at_extreme_sparsity():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    z = A.project_prune_block(w, 0.97, 4, 4)  # target = 2 elements
    assert int(jnp.sum(z != 0)) == 16, "the single best tile must survive"


@settings(max_examples=15, deadline=None)
@given(
    cin=st.integers(1, 8),
    cout=st.integers(1, 16),
    sparsity=st.floats(0.6, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_pattern_projection_constraint_set(cin, cout, sparsity, seed):
    """PatDNN projection invariants: every surviving 3x3 kernel keeps
    exactly `entries` positions drawn from a library of at most
    `library_size` distinct masks; kept values are untouched; achieved
    nnz is within half a pattern of the target (floor of one kernel)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(3, 3, cin, cout)), jnp.float32)
    z = A.project_prune_pattern(w, sparsity, entries=4, library_size=8)
    zk = np.asarray(z).reshape(9, -1)
    wk = np.asarray(w).reshape(9, -1)
    masks = set()
    for j in range(zk.shape[1]):
        nz = np.nonzero(zk[:, j])[0]
        assert len(nz) in (0, 4), f"kernel {j} has {len(nz)} entries"
        if len(nz):
            masks.add(tuple(nz.tolist()))
            np.testing.assert_array_equal(zk[nz, j], wk[nz, j])
    assert len(masks) <= 8
    nnz = int(jnp.sum(z != 0))
    target = max(1, int(np.floor(w.size * (1.0 - sparsity) + 0.5)))
    n_keep = min(cin * cout, max(1, int(np.floor(target / 4.0 + 0.5))))
    assert nnz == 4 * n_keep


def test_pattern_projection_falls_back_on_fc_weights():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(100, 40)), jnp.float32)
    z = A.project_prune_pattern(w, 0.9, entries=4, library_size=8)
    ze = A.project_prune_element(w, 0.9)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(ze))


def test_pattern_library_selection_is_deterministic_and_bounded():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)
    lib1 = A.select_pattern_library(w, entries=4, library_size=6)
    lib2 = A.select_pattern_library(w, entries=4, library_size=6)
    np.testing.assert_array_equal(lib1, lib2)
    assert lib1.shape[1] == 9 and lib1.shape[0] <= 6
    assert (lib1.sum(axis=1) == 4).all()


def test_structures_exported_per_layer(digit_task):
    """CompressResult.structures records what each layer actually got:
    pattern for conv (4D) weights, element fallback for FC."""
    fwd, params, x, y, _xt, _yt = digit_task
    cfg = A.AdmmConfig(
        sparsity={"c1": 0.7, "f1": 0.9},
        granularity="pattern",
        admm_iters=1,
        epochs_per_iter=1,
        retrain_epochs=1,
        seed=0,
    )
    res = A.admm_prune(fwd, params, x, y, cfg)
    assert res.structures["c1"] == "pattern4"
    assert res.structures["f1"] == "element"
    # the exported labels parse on the Rust side (PruneStructure::parse
    # accepts "pattern{entries}" / "element"); pin the exact strings
    assert set(res.structures.values()) <= {"pattern4", "element"}


def test_quantize_projection_levels():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    q, step = A.project_quantize(w, 4)
    lv = np.asarray(q) / float(step)
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-5)
    assert np.abs(lv).max() <= 7  # 2^(4-1) - 1


def test_quantize_preserves_zero_support():
    w = jnp.asarray([0.0, 0.5, 0.0, -0.7], jnp.float32)
    q, _ = A.project_quantize(w, 4)
    assert float(q[0]) == 0.0 and float(q[2]) == 0.0


def test_quantize_is_nearest_level():
    w = jnp.asarray([0.31, -0.49, 1.0], jnp.float32)
    q, step = A.project_quantize(w, 3)
    np.testing.assert_allclose(
        np.asarray(q), np.clip(np.round(np.asarray(w) / step), -3, 3) * step, rtol=1e-6
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 400),
    bits=st.integers(2, 8),
    sparsity=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_codebook_export_bounded_and_exact(n, bits, sparsity, seed):
    """The codebook export invariants the Rust importer relies on:
    at most 2^bits - 1 ascending nonzero levels, zero never exported,
    and every nonzero quantized value is reconstructible from the
    codebook (the LUT kernels' contract)."""
    rng = np.random.default_rng(seed)
    w = A.project_prune_element(
        jnp.asarray(rng.normal(size=(n,)), jnp.float32), sparsity
    )
    q, _ = A.project_quantize(w, bits)
    cb = A.codebook_of(q, bits)
    assert len(cb) <= 2**bits - 1
    assert (np.diff(cb) > 0).all() if len(cb) > 1 else True
    assert not np.any(cb == 0.0), "zero is the reserved support level"
    nz = np.asarray(q)[np.asarray(q) != 0.0]
    assert np.isin(nz, cb).all(), "every nonzero value must be in the codebook"


def test_codebook_of_rejects_overwide_tables():
    # 4 distinct nonzero values cannot ship as a 2-bit codebook (max 3)
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    with pytest.raises(AssertionError):
        A.codebook_of(w, 2)


def test_export_quant_shapes_report_entries():
    """export_quant emits exactly what compress_report.json ships and
    SparsityProfile::from_report parses: {"bits", "codebook"} per layer,
    JSON-serializable floats, codebook within the declared width."""
    import json

    q, _ = A.project_quantize(
        jnp.asarray(np.linspace(-1.0, 1.0, 50), jnp.float32), 4
    )
    params = {"c1": {"w": q}, "f1": {"w": q * 0.5}}
    out = A.export_quant(params, ["c1", "f1"], 4)
    assert set(out) == {"c1", "f1"}
    for entry in out.values():
        assert entry["bits"] == 4
        assert len(entry["codebook"]) <= 15
        assert all(isinstance(v, float) for v in entry["codebook"])
    json.dumps(out)  # must be serializable as-is


# ------------------------------------------------- end-to-end (small)


@pytest.fixture(scope="module")
def digit_task():
    x, y = D.synthetic_digits(600, seed=1)
    xt, yt = D.synthetic_digits(300, seed=2)
    fwd = lambda p, xx: M.lenet5_apply(p, xx, backend="ref")
    p = M.lenet5_init(0)
    p, _ = T.train(fwd, p, x, y, epochs=4)
    return fwd, p, x, y, xt, yt


def test_admm_feasibility_and_recovery(digit_task):
    """After masked mapping + retraining, every layer satisfies its
    sparsity constraint EXACTLY, and accuracy stays near dense."""
    fwd, p, x, y, xt, yt = digit_task
    dense_acc = T.accuracy(fwd, p, xt, yt)
    sparsity = {"c1": 0.3, "c2": 0.6, "f1": 0.9, "f2": 0.8}
    cfg = A.AdmmConfig(
        sparsity=sparsity, admm_iters=2, epochs_per_iter=1, retrain_epochs=3
    )
    res = A.admm_prune(fwd, dict(p), x, y, cfg)
    for k, target in sparsity.items():
        nnz, total = res.per_layer_nnz[k]
        achieved = 1.0 - nnz / total
        assert achieved >= target - 0.02, f"{k}: {achieved} < {target}"
    acc = T.accuracy(fwd, res.params, xt, yt)
    assert acc >= dense_acc - 0.08, f"accuracy collapsed: {acc} vs {dense_acc}"


def test_admm_masked_weights_stay_zero(digit_task):
    fwd, p, x, y, xt, yt = digit_task
    cfg = A.AdmmConfig(
        sparsity={"f1": 0.95}, admm_iters=1, epochs_per_iter=1, retrain_epochs=2
    )
    res = A.admm_prune(fwd, dict(p), x, y, cfg)
    w = np.asarray(res.params["f1"]["w"])
    m = np.asarray(res.masks["f1"])
    assert np.all(w[m == 0] == 0.0)


def test_admm_unified_quantization(digit_task):
    fwd, p, x, y, xt, yt = digit_task
    cfg = A.AdmmConfig(
        sparsity={"f1": 0.9, "f2": 0.8},
        admm_iters=1,
        epochs_per_iter=1,
        retrain_epochs=1,
        quant_bits=4,
    )
    res = A.admm_prune(fwd, dict(p), x, y, cfg)
    for k in ("f1", "f2"):
        w = np.asarray(res.params[k]["w"])
        nz = w[w != 0]
        # all non-zeros on a 15-level grid
        step = np.abs(nz).max() / 7
        np.testing.assert_allclose(nz / step, np.round(nz / step), atol=1e-4)


def test_multi_rho_tightens_gap():
    """On a convex toy problem, ||W - Z|| shrinks as rho grows."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(40,)), jnp.float32)
    w = jnp.zeros((40,))
    z = A.project_prune_element(w, 0.8)
    u = jnp.zeros_like(w)
    rho = 0.1
    gaps = []
    for _ in range(12):
        # x-step: closed form for min ||w-target||^2 + rho/2 ||w-z+u||^2
        w = (2 * target + rho * (z - u)) / (2 + rho)
        z = A.project_prune_element(w + u, 0.8)
        u = u + w - z
        gaps.append(float(jnp.sum((w - z) ** 2)))
        rho *= 1.7
    assert gaps[-1] < gaps[0] * 0.05


# ------------------------------------------------------------- storage


def test_storage_accounting():
    assert A.storage_bytes_dense(1000) == 4000
    assert A.storage_bytes_compressed(100, 4) == 50
    assert A.storage_bytes_compressed(100, 4, index_bits=16) == 250


def test_overall_rate():
    res = A.CompressResult(
        params={}, masks={}, history=[],
        per_layer_nnz={"a": (10, 1000), "b": (10, 1000)},
    )
    assert res.overall_rate == 100.0
