"""Synthetic dataset invariants."""

import numpy as np
import pytest

from compile import datasets as D


def test_digits_shapes_and_range():
    x, y = D.synthetic_digits(50, seed=0)
    assert x.shape == (50, 28, 28, 1)
    assert y.shape == (50,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))


def test_digits_deterministic():
    x1, y1 = D.synthetic_digits(20, seed=7)
    x2, y2 = D.synthetic_digits(20, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = D.synthetic_digits(20, seed=8)
    assert not np.array_equal(x1, x3)


def test_digits_custom_size():
    x, _ = D.synthetic_digits(5, seed=0, size=32)
    assert x.shape == (5, 32, 32, 1)


def test_digits_learnable_signal():
    """Same-class images correlate more than cross-class (i.e. the task
    carries signal — not pure noise)."""
    x, y = D.synthetic_digits(300, seed=1)
    flat = x.reshape(len(x), -1)
    # class-mean templates
    means = np.stack([flat[y == d].mean(axis=0) for d in range(10)])
    preds = np.argmax(flat @ means.T, axis=1)
    acc = (preds == y).mean()
    # digits are randomly translated, so raw-pixel templates are weak —
    # but still far above the 10% chance floor
    assert acc > 0.2, f"template accuracy {acc}"


def test_seeded_images_shape_and_determinism():
    a = D.seeded_images(3, 16, 16, 3, seed=2)
    b = D.seeded_images(3, 16, 16, 3, seed=2)
    assert a.shape == (3, 16, 16, 3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0.0 and a.max() <= 1.0
